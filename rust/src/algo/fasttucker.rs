//! **FastTucker** — the paper's algorithm (Algorithm 1): stochastic SGD
//! over sampled nonzeros with the Kruskal-factored core and the Theorem-1/2
//! contraction reduction.
//!
//! Per sampled nonzero `(i_1..i_N, x)` the update costs `O(N·R_core·J)`:
//!
//! 1. `c[n][r] = b_r^(n) · a_{i_n}^(n)` — N·R dot products of length J
//!    (the warp-shuffle step of the CUDA kernel).
//! 2. `w[n][r] = Π_{m≠n} c[m][r]` via prefix/suffix products — O(N·R)
//!    total, an improvement over Algorithm 1's per-mode recomputation
//!    (O(N²·R)); numerically identical — see
//!    `tests::prefix_suffix_identity`.
//! 3. `GS^(n) = Σ_r w[n][r] · b_r^(n)` — the factor-update coefficient
//!    (paper Fig. 1 left).
//! 4. `x̂ = a^(1) · GS^(1)`, `e = x̂ - x`; factor row SGD (Eq. 13).
//! 5. Core gradients `∂/∂b_r^(n) = e · w[n][r] · a^(n)` (Eq. 17, where
//!    `w·a` is the paper's `Q^(n),r` vector, Fig. 1 right), accumulated
//!    over the epoch and applied with `M = |Ψ|` (Algorithm 1).
//!
//! The factor rows for the current sample are staged into a compact
//! `order × J` buffer first (the GPU kernel's shared-memory gather); the
//! contraction and the core gradient then read only the staged pre-update
//! values, which also lets the multi-device engine ([`crate::parallel`])
//! and the PJRT engine reuse the identical math through
//! [`contract_staged`].
//!
//! The [`CoreLayout`] switch reproduces the paper's shared-vs-global-memory
//! ablation (Tables 8–12): `Packed` walks `b_r^(n)` as contiguous rows
//! (shared-memory analogue), `Strided` reads a column-major copy with
//! stride `R_core` (global-memory analogue).

use std::time::Instant;

use crate::algo::{Decomposer, EpochStats, SgdHyper};
use crate::kruskal::KruskalCore;
use crate::model::{CoreRepr, TuckerModel};
use crate::sched::Sampler;
use crate::tensor::SparseTensor;
use crate::util::linalg::{axpy, dot, scale_axpy};
use crate::util::Rng;

/// Memory layout of the hot Kruskal factors (Tables 8–12 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoreLayout {
    /// Contiguous `b_r^(n)` rows (paper: core factors in shared memory).
    Packed,
    /// Column-major copy, stride `R_core` between elements of one `b_r^(n)`
    /// (paper: core factors in global memory, uncoalesced).
    Strided,
}

/// Configuration of the FastTucker decomposer.
#[derive(Clone, Copy, Debug)]
pub struct FastTuckerConfig {
    pub hyper: SgdHyper,
    pub layout: CoreLayout,
}

impl Default for FastTuckerConfig {
    fn default() -> Self {
        FastTuckerConfig { hyper: SgdHyper::default(), layout: CoreLayout::Packed }
    }
}

/// Reusable scratch for the per-sample update — everything the CUDA kernel
/// would keep in registers/shared memory, preallocated so the hot loop
/// never allocates.
pub struct Workspace {
    pub(crate) order: usize,
    pub(crate) r_core: usize,
    pub(crate) j: usize,
    /// Staged factor rows for the current sample, `[n][j]`.
    pub(crate) a_stage: Vec<f32>,
    /// `c[n*R + r]`.
    c: Vec<f32>,
    /// Prefix products `pre[n*R + r] = Π_{m<n} c[m][r]`.
    pre: Vec<f32>,
    /// Suffix products.
    suf: Vec<f32>,
    /// `w[n*R + r] = Π_{m≠n} c[m][r]`.
    pub(crate) w: Vec<f32>,
    /// `gs[n*J .. (n+1)*J]`.
    pub(crate) gs: Vec<f32>,
    /// Core gradient accumulator, `[n][r][j]` flattened.
    pub(crate) core_grad: Vec<f32>,
    /// Number of samples accumulated into `core_grad`.
    pub(crate) core_grad_count: usize,
}

impl Workspace {
    pub fn new(order: usize, r_core: usize, j: usize) -> Self {
        Workspace {
            order,
            r_core,
            j,
            a_stage: vec![0.0; order * j],
            c: vec![0.0; order * r_core],
            pre: vec![0.0; (order + 1) * r_core],
            suf: vec![0.0; (order + 1) * r_core],
            w: vec![0.0; order * r_core],
            gs: vec![0.0; order * j],
            core_grad: vec![0.0; order * r_core * j],
            core_grad_count: 0,
        }
    }

    /// `GS^(n)` of the last contraction.
    #[inline]
    pub fn gs_row(&self, n: usize) -> &[f32] {
        &self.gs[n * self.j..(n + 1) * self.j]
    }

    /// Staged row for mode `n`.
    #[inline]
    pub fn staged_row(&self, n: usize) -> &[f32] {
        &self.a_stage[n * self.j..(n + 1) * self.j]
    }

    /// Stage one mode's factor row.
    #[inline]
    pub fn stage_row(&mut self, n: usize, row: &[f32]) {
        self.a_stage[n * self.j..(n + 1) * self.j].copy_from_slice(row);
    }
}

/// The Thm-1/2 contraction for one staged sample. Reads `ws.a_stage`,
/// fills `ws.{c, w, gs}`, returns the residual `e = x̂ - x`.
///
/// `strided` is only consulted under [`CoreLayout::Strided`] and must hold
/// the column-major mirror of `core` (see [`build_strided`]).
pub fn contract_staged(
    ws: &mut Workspace,
    core: &KruskalCore,
    strided: &[Vec<f32>],
    layout: CoreLayout,
    x: f32,
) -> f32 {
    let order = ws.order;
    let r_core = ws.r_core;
    let j = ws.j;

    // Step 1: c[n][r] = b_r^(n) · a_{i_n} — a register-blocked matvec
    // against the contiguous B^(n) under the Packed layout.
    for n in 0..order {
        let a_row = &ws.a_stage[n * j..(n + 1) * j];
        match layout {
            CoreLayout::Packed => {
                crate::util::linalg::matvec_rowmajor(
                    core.factor(n).data(),
                    r_core,
                    j,
                    a_row,
                    &mut ws.c[n * r_core..(n + 1) * r_core],
                );
            }
            CoreLayout::Strided => {
                let col = &strided[n];
                for r in 0..r_core {
                    let mut acc = 0.0f32;
                    for (jj, &av) in a_row.iter().enumerate() {
                        acc += col[jj * r_core + r] * av;
                    }
                    ws.c[n * r_core + r] = acc;
                }
            }
        }
    }

    // Step 2: prefix/suffix products -> w[n][r].
    for r in 0..r_core {
        ws.pre[r] = 1.0;
    }
    for n in 0..order {
        for r in 0..r_core {
            ws.pre[(n + 1) * r_core + r] = ws.pre[n * r_core + r] * ws.c[n * r_core + r];
        }
    }
    for r in 0..r_core {
        ws.suf[order * r_core + r] = 1.0;
    }
    for n in (0..order).rev() {
        for r in 0..r_core {
            ws.suf[n * r_core + r] = ws.suf[(n + 1) * r_core + r] * ws.c[n * r_core + r];
        }
    }
    for n in 0..order {
        for r in 0..r_core {
            ws.w[n * r_core + r] = ws.pre[n * r_core + r] * ws.suf[(n + 1) * r_core + r];
        }
    }

    // Step 3: GS^(n) = Σ_r w[n][r] b_r^(n) — 4-row blocked weighted sum
    // under the Packed layout.
    ws.gs.fill(0.0);
    for n in 0..order {
        match layout {
            CoreLayout::Packed => {
                crate::util::linalg::weighted_rowsum(
                    core.factor(n).data(),
                    r_core,
                    j,
                    &ws.w[n * r_core..(n + 1) * r_core],
                    &mut ws.gs[n * j..(n + 1) * j],
                );
            }
            CoreLayout::Strided => {
                let col = &strided[n];
                for jj in 0..j {
                    let mut acc = 0.0f32;
                    for r in 0..r_core {
                        acc += ws.w[n * r_core + r] * col[jj * r_core + r];
                    }
                    ws.gs[n * j + jj] = acc;
                }
            }
        }
    }

    // Step 4: prediction and residual (mode-invariant; use mode 0).
    let xhat = dot(&ws.a_stage[0..j], &ws.gs[0..j]);
    xhat - x
}

/// Accumulate the Eq. 17 core gradient for the last contraction into
/// `ws.core_grad` (uses the staged *pre-update* rows).
#[inline]
pub fn accumulate_core_grad(ws: &mut Workspace, e: f32) {
    let (order, r_core, j) = (ws.order, ws.r_core, ws.j);
    for n in 0..order {
        let (head, grads) = ws.core_grad.split_at_mut(n * r_core * j);
        let _ = head;
        let a_row = &ws.a_stage[n * j..(n + 1) * j];
        for r in 0..r_core {
            let coef = e * ws.w[n * r_core + r];
            axpy(coef, a_row, &mut grads[r * j..(r + 1) * j]);
        }
    }
    ws.core_grad_count += 1;
}

/// Apply the accumulated core gradient to `core` (Algorithm 1's batched
/// core update with `M = |Ψ|`): `b <- (1-lr·λ)b - lr·Σe·w·a / M`.
pub fn apply_core_grad(ws: &mut Workspace, core: &mut KruskalCore, lr_c: f32, lam_c: f32) {
    if ws.core_grad_count == 0 {
        return;
    }
    let m = ws.core_grad_count as f32;
    let (order, r_core, j) = (ws.order, ws.r_core, ws.j);
    for n in 0..order {
        for r in 0..r_core {
            let g = &ws.core_grad[(n * r_core + r) * j..(n * r_core + r + 1) * j];
            let row = core.row_mut(n, r);
            for (bi, &gi) in row.iter_mut().zip(g.iter()) {
                *bi = (1.0 - lr_c * lam_c) * *bi - lr_c * gi / m;
            }
        }
    }
    ws.core_grad.fill(0.0);
    ws.core_grad_count = 0;
}

/// Build the column-major mirror used by [`CoreLayout::Strided`]:
/// `out[n][j*R + r] = b^(n)[r][j]`.
pub fn build_strided(core: &KruskalCore) -> Vec<Vec<f32>> {
    let order = core.order();
    let r_core = core.rank();
    (0..order)
        .map(|n| {
            let j = core.j(n);
            let mut buf = vec![0.0f32; j * r_core];
            for r in 0..r_core {
                for (jj, &v) in core.row(n, r).iter().enumerate() {
                    buf[jj * r_core + r] = v;
                }
            }
            buf
        })
        .collect()
}

/// The FastTucker decomposer.
pub struct FastTucker {
    pub config: FastTuckerConfig,
    ws: Option<Workspace>,
    strided: Vec<Vec<f32>>,
}

impl FastTucker {
    pub fn new(config: FastTuckerConfig) -> Self {
        FastTucker { config, ws: None, strided: Vec::new() }
    }

    pub fn with_defaults() -> Self {
        Self::new(FastTuckerConfig::default())
    }

    fn ensure_ws(&mut self, order: usize, r_core: usize, j: usize) {
        let stale = match &self.ws {
            Some(w) => w.order != order || w.r_core != r_core || w.j != j,
            None => true,
        };
        if stale {
            self.ws = Some(Workspace::new(order, r_core, j));
        }
    }

    /// Process one sample: stage rows, contract, optional core-grad
    /// accumulation, factor SGD write-back.
    #[inline]
    fn step_sample(
        ws: &mut Workspace,
        strided: &[Vec<f32>],
        layout: CoreLayout,
        model: &mut TuckerModel,
        coords: &[u32],
        x: f32,
        lr_f: f32,
        lam_f: f32,
        accumulate_core: bool,
    ) {
        let order = ws.order;
        for n in 0..order {
            let row = model.factors.row(n, coords[n] as usize);
            ws.a_stage[n * ws.j..(n + 1) * ws.j].copy_from_slice(row);
        }
        let e = {
            let core = match &model.core {
                CoreRepr::Kruskal(k) => k,
                CoreRepr::Dense(_) => panic!("FastTucker requires a Kruskal core"),
            };
            contract_staged(ws, core, strided, layout, x)
        };
        if accumulate_core {
            accumulate_core_grad(ws, e);
        }
        let j = ws.j;
        for n in 0..order {
            let gs_n = &ws.gs[n * j..(n + 1) * j];
            let row = model.factors.row_mut(n, coords[n] as usize);
            scale_axpy(1.0 - lr_f * lam_f, -lr_f * e, gs_n, row);
        }
    }
}

impl Decomposer for FastTucker {
    fn name(&self) -> &'static str {
        "fasttucker"
    }

    fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        epoch: usize,
        rng: &mut Rng,
    ) -> EpochStats {
        let (order, r_core, j) = match &model.core {
            CoreRepr::Kruskal(k) => (k.order(), k.rank(), k.j(0)),
            CoreRepr::Dense(_) => panic!("FastTucker requires TuckerModel::init_kruskal"),
        };
        self.ensure_ws(order, r_core, j);
        if self.config.layout == CoreLayout::Strided {
            let core = match &model.core {
                CoreRepr::Kruskal(k) => k,
                _ => unreachable!(),
            };
            self.strided = build_strided(core);
        }

        let h = self.config.hyper;
        let lr_f = h.lr_factor.at(epoch);
        let lr_c = h.lr_core.at(epoch);
        let sampler = Sampler::new(train.nnz());
        let m = ((train.nnz() as f64) * h.sample_frac).round().max(1.0) as usize;
        let psi = if h.sample_frac >= 1.0 {
            let mut ids: Vec<usize> = (0..train.nnz()).collect();
            rng.shuffle(&mut ids);
            ids
        } else {
            sampler.one_step(rng, m)
        };

        let ws = self.ws.as_mut().unwrap();
        let t0 = Instant::now();
        for &k in &psi {
            Self::step_sample(
                ws,
                &self.strided,
                self.config.layout,
                model,
                train.index(k),
                train.value(k),
                lr_f,
                h.lambda_factor,
                h.update_core,
            );
        }
        let factor_secs = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        if h.update_core {
            let core = match &mut model.core {
                CoreRepr::Kruskal(k) => k,
                _ => unreachable!(),
            };
            apply_core_grad(ws, core, lr_c, h.lambda_core);
            if self.config.layout == CoreLayout::Strided {
                self.strided = build_strided(core);
            }
        }
        let core_secs = t1.elapsed().as_secs_f64();

        EpochStats { samples: psi.len(), factor_secs, core_secs }
    }

    fn updates_core(&self) -> bool {
        self.config.hyper.update_core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::kruskal::reconstruct::rmse;
    use crate::util::propcheck::forall;

    fn planted(seed: u64, order: usize) -> (crate::data::synth::Planted, PlantedSpec) {
        let spec = PlantedSpec {
            dims: vec![30; order],
            nnz: 4000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(seed);
        (planted_tucker(&mut rng, &spec), spec)
    }

    #[test]
    fn converges_on_planted_order3() {
        let (p, spec) = planted(1, 3);
        let mut rng = Rng::new(2);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
        algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
        let before = rmse(&model, &p.tensor);
        for epoch in 0..30 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng);
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.5 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn converges_on_planted_order4() {
        let spec = PlantedSpec {
            dims: vec![15; 4],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(3);
        let p = planted_tucker(&mut rng, &spec);
        let mut rng = Rng::new(4);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.05);
        algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.02);
        // Per-sample L2 decay compounds ~(1-lr·λ)^(nnz/dim) per epoch; at
        // order 4 the gradient signal is weak at init, so a large λ would
        // collapse the model to zero before it can fit. Use a small one.
        algo.config.hyper.lambda_factor = 1e-4;
        algo.config.hyper.lambda_core = 1e-4;
        let before = rmse(&model, &p.tensor);
        for epoch in 0..50 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng);
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.6 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn strided_layout_matches_packed_numerically() {
        let (p, spec) = planted(5, 3);
        let make = |layout| {
            let mut rng = Rng::new(6);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut cfg = FastTuckerConfig::default();
            cfg.layout = layout;
            let mut algo = FastTucker::new(cfg);
            let mut rng2 = Rng::new(7);
            for epoch in 0..3 {
                algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng2);
            }
            rmse(&model, &p.tensor)
        };
        let packed = make(CoreLayout::Packed);
        let strided = make(CoreLayout::Strided);
        assert!(
            (packed - strided).abs() < 1e-5,
            "layouts diverged: {packed} vs {strided}"
        );
    }

    #[test]
    fn matches_cutucker_with_equivalent_core() {
        // FastTucker with Kruskal core K and cuTucker with dense(K) compute
        // the same factor gradients: one epoch with the same sample order
        // and frozen cores must give identical factors (to f32 tolerance).
        let (p, spec) = planted(12, 3);
        let mut rng = Rng::new(13);
        let kmodel = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let kcore = match &kmodel.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let dmodel = TuckerModel {
            factors: kmodel.factors.clone(),
            core: CoreRepr::Dense(kcore.to_dense()),
        };

        let mut m1 = kmodel;
        let mut a1 = FastTucker::with_defaults();
        a1.config.hyper.update_core = false;
        let mut r1 = Rng::new(99);
        a1.train_epoch(&mut m1, &p.tensor, 0, &mut r1);

        let mut m2 = dmodel;
        let mut a2 = crate::algo::CuTucker::with_defaults();
        a2.hyper.update_core = false;
        let mut r2 = Rng::new(99);
        a2.train_epoch(&mut m2, &p.tensor, 0, &mut r2);

        for n in 0..3 {
            for (x, y) in m1
                .factors
                .mat(n)
                .data()
                .iter()
                .zip(m2.factors.mat(n).data().iter())
            {
                assert!((x - y).abs() < 2e-3, "mode {n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn factor_only_mode_leaves_core_untouched() {
        let (p, spec) = planted(8, 3);
        let mut rng = Rng::new(9);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let core_before = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.update_core = false;
        algo.train_epoch(&mut model, &p.tensor, 0, &mut rng);
        let core_after = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        for n in 0..3 {
            assert_eq!(core_before.factor(n).data(), core_after.factor(n).data());
        }
    }

    #[test]
    fn sampled_epoch_visits_m_samples() {
        let (p, spec) = planted(10, 3);
        let mut rng = Rng::new(11);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.sample_frac = 0.25;
        let stats = algo.train_epoch(&mut model, &p.tensor, 0, &mut rng);
        assert_eq!(stats.samples, 1000);
    }

    #[test]
    fn prefix_suffix_identity() {
        // w[n][r] computed by prefix/suffix equals the direct product
        // over m != n (what Algorithm 1 recomputes per mode).
        forall("prefix/suffix == direct leave-one-out product", 64, |rng| {
            let order = 2 + rng.gen_range(5);
            let r_core = 1 + rng.gen_range(6);
            let c: Vec<f32> = (0..order * r_core).map(|_| 0.2 + rng.uniform()).collect();
            let mut direct = vec![0.0f32; order * r_core];
            for n in 0..order {
                for r in 0..r_core {
                    let mut prod = 1.0f32;
                    for m in 0..order {
                        if m != n {
                            prod *= c[m * r_core + r];
                        }
                    }
                    direct[n * r_core + r] = prod;
                }
            }
            let mut pre = vec![1.0f32; (order + 1) * r_core];
            let mut suf = vec![1.0f32; (order + 1) * r_core];
            for n in 0..order {
                for r in 0..r_core {
                    pre[(n + 1) * r_core + r] = pre[n * r_core + r] * c[n * r_core + r];
                }
            }
            for n in (0..order).rev() {
                for r in 0..r_core {
                    suf[n * r_core + r] = suf[(n + 1) * r_core + r] * c[n * r_core + r];
                }
            }
            for n in 0..order {
                for r in 0..r_core {
                    let w = pre[n * r_core + r] * suf[(n + 1) * r_core + r];
                    let rel = (w - direct[n * r_core + r]).abs()
                        / direct[n * r_core + r].abs().max(1e-6);
                    assert!(rel < 1e-4, "n={n} r={r}");
                }
            }
        });
    }

    #[test]
    fn contract_staged_prediction_matches_dense_core() {
        // Thm 1/2 identity at the Rust layer: linear-path x̂ equals the
        // exponential dense-core prediction.
        let mut rng = Rng::new(20);
        let model = TuckerModel::init_kruskal(&mut rng, &[10, 11, 12], 4, 3);
        let core = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let dense = core.to_dense();
        let mut ws = Workspace::new(3, 3, 4);
        for coords in [[0u32, 0, 0], [9, 10, 11], [5, 6, 7]] {
            for n in 0..3 {
                ws.stage_row(n, model.factors.row(n, coords[n] as usize));
            }
            let e = contract_staged(&mut ws, &core, &[], CoreLayout::Packed, 0.0);
            let want = dense.predict(&model.factors, &coords);
            assert!((e - want).abs() < 1e-4, "{e} vs {want}");
        }
    }
}
