//! **FastTucker** — the paper's algorithm (Algorithm 1): stochastic SGD
//! over sampled nonzeros with the Kruskal-factored core and the Theorem-1/2
//! contraction reduction.
//!
//! The per-sample math lives in the shared kernel layer
//! ([`crate::kernel`]): this decomposer builds the epoch's sampling set Ψ
//! and dispatches it to either
//!
//! * the **scalar** kernel ([`crate::kernel::scalar`]) — one nonzero at a
//!   time in Ψ order (the paper's Algorithm 1 semantics), or
//! * the **batched** kernel ([`crate::kernel::batched`]) when
//!   [`FastTuckerConfig::batch`] is `Auto` or `Fixed(n ≥ 2)` — Ψ is
//!   grouped into tiles of mode-1 fibers ([`crate::kernel::BatchPlan`],
//!   cap and tile width from the planner under `Auto`), each fiber's
//!   shared factor row staged once per sub-run, with the contraction
//!   running over `batch × R_core` panels (cuFasterTucker's batching,
//!   arXiv:2210.06014). Under [`FastTuckerConfig::exactness`]` = Exact`
//!   (default) this is bitwise identical to the scalar path over the same
//!   grouped order; `Relaxed` opts into the paper's hogwild semantics for
//!   longer groups on hollow tensors.
//!
//! The [`CoreLayout`] switch reproduces the paper's shared-vs-global-memory
//! ablation (Tables 8–12) on both paths.

use std::time::Instant;

use crate::algo::{AlgoError, AlgoResult, Decomposer, EpochStats, SgdHyper};
use crate::kernel::{
    apply_core_grad_raw, planner, scalar, BatchPlan, BatchSizing, DispatchPool, Exactness,
    Lanes, PlanParams, SimdLevel, ThreadCount,
};
use crate::log_warn;
use crate::parallel::shared::{dispatch_plan, SharedFactors};
use crate::parallel::DeviceCount;
// Re-exported for compatibility: the contraction primitives historically
// lived in this module and are widely imported from here.
pub use crate::kernel::contract::{
    accumulate_core_grad, apply_core_grad, build_strided, contract_staged, CoreLayout,
    Workspace,
};

use crate::metrics::PlanStats;
use crate::model::{CoreRepr, TuckerModel};
use crate::sched::Sampler;
use crate::tensor::SparseTensor;
use crate::util::Rng;

/// Configuration of the FastTucker decomposer.
#[derive(Clone, Copy, Debug)]
pub struct FastTuckerConfig {
    pub hyper: SgdHyper,
    pub layout: CoreLayout,
    /// Batch-group sizing. `Fixed(0)`/`Fixed(1)` select the scalar kernel
    /// (Ψ processed in draw order, the legacy semantics); `Fixed(n ≥ 2)`
    /// pins a single-fiber group cap; `Auto` lets the planner pick cap
    /// and fiber-tile width from the dataset's fiber statistics
    /// ([`crate::kernel::planner`]).
    pub batch: BatchSizing,
    /// Collision semantics of the batched plans: `Exact` (bitwise equal
    /// to scalar over plan order, the default) or `Relaxed` (hogwild,
    /// longer groups). Ignored on the scalar path.
    pub exactness: Exactness,
    /// Panel-microkernel lane width ([`crate::kernel::panel`]): `Auto`
    /// (planner picks from `R_core`, the default) or an explicit 4/8.
    /// Ignored on the scalar path; bitwise-neutral in exact mode.
    pub lanes: Lanes,
    /// Panel-microkernel instruction set ([`SimdLevel`]): `Auto` (runtime
    /// detection, overridable via `FASTTUCKER_SIMD`), `Scalar`, `V128`, or
    /// `V256`. Every level is bitwise-identical, so this is a pure
    /// performance knob. Ignored on the scalar path.
    pub simd: SimdLevel,
    /// Mixed-precision accumulation (ISSUE 10): store factors in f32 but
    /// accumulate the per-sample contractions in f64 on the relaxed path.
    /// Rejected with `Exact` (it changes the bit pattern by design);
    /// forces sequential execution (the wide path has no panel kernels).
    pub wide_accum: bool,
    /// Split-group factor (≥ 1, default 1 = off): long groups are cut at
    /// fiber sub-run boundaries (exact; bitwise-neutral) or anywhere
    /// (relaxed) into `split` sub-groups — the dispatch unit for
    /// intra-group parallelism (see [`crate::kernel::plan::PlanParams`]).
    pub split: usize,
    /// In-group thread pool width (ISSUE 4 tentpole): the serial engine
    /// fans each epoch plan's split sub-groups across this many threads
    /// through a [`DispatchPool`] — exact mode via the sub-group coloring
    /// waves (bitwise identical to sequential execution), relaxed mode as
    /// one hogwild wave. `Auto` = `FASTTUCKER_POOL_THREADS` or
    /// sequential. Ignored on the scalar path.
    pub threads: ThreadCount,
    /// Device-shard grid width (ISSUE 5; config-surface parity with the
    /// parallel engine, which owns the real
    /// [`DeviceGrid`](crate::parallel::DeviceGrid) implementation). The
    /// serial engine
    /// IS a single device: `Auto` resolves to 1 here, and a fixed
    /// `N > 1` is a degenerate request that degrades loudly — one
    /// warning plus [`PlanStats::degraded`] — instead of erroring, so a
    /// shared TOML can flip `engine` without re-editing `devices`.
    pub devices: DeviceCount,
}

impl Default for FastTuckerConfig {
    fn default() -> Self {
        FastTuckerConfig {
            hyper: SgdHyper::default(),
            layout: CoreLayout::Packed,
            batch: BatchSizing::Fixed(0),
            exactness: Exactness::Exact,
            lanes: Lanes::Auto,
            simd: SimdLevel::Auto,
            wide_accum: false,
            split: 1,
            threads: ThreadCount::Auto,
            devices: DeviceCount::Auto,
        }
    }
}

/// The FastTucker decomposer.
pub struct FastTucker {
    pub config: FastTuckerConfig,
    ws: Option<Workspace>,
    /// Batched-path executor state: the in-group pool (T = 1 degenerates
    /// to the plain per-epoch workspace of earlier PRs).
    pool: Option<DispatchPool>,
    strided: Vec<Vec<f32>>,
    /// Planner decision cached per workload + model fingerprint
    /// `(revision, nnz, dims, sample count, order, r_core, j, exactness,
    /// lanes, simd, wide_accum, split)` — every input the cost model
    /// reads, so mutating
    /// `config`, switching models, or feeding different nonzeros (the
    /// content revision — even at identical `(nnz, dims)`) invalidates
    /// it.
    #[allow(clippy::type_complexity)]
    auto_cache: Option<(
        (u64, usize, Vec<usize>, usize, usize, usize, usize, Exactness, Lanes, SimdLevel, bool, usize),
        PlanParams,
    )>,
    /// Lifetime count of planner re-decisions (cache-invalidation
    /// observability, ISSUE 9).
    planner_rebuilds: u64,
    /// Plan of the most recent batched epoch (observability).
    last_plan_stats: Option<PlanStats>,
    /// One-shot guard for the degenerate `devices > 1` warning.
    warned_devices: bool,
}

impl FastTucker {
    pub fn new(config: FastTuckerConfig) -> Self {
        FastTucker {
            config,
            ws: None,
            pool: None,
            strided: Vec::new(),
            auto_cache: None,
            planner_rebuilds: 0,
            last_plan_stats: None,
            warned_devices: false,
        }
    }

    /// How many times the planner cache missed and re-decided (0 until
    /// the first `Auto` epoch; stays flat while the workload fingerprint
    /// — including the tensor's content revision — is unchanged).
    pub fn planner_rebuilds(&self) -> u64 {
        self.planner_rebuilds
    }

    /// The serial engine is one device: a fixed multi-device request is
    /// degenerate here — warn once and report it through
    /// [`PlanStats::degraded`] (ISSUE 5 degenerate-grid satellite).
    fn devices_degraded(&mut self) -> bool {
        match self.config.devices {
            DeviceCount::Fixed(d) if d > 1 => {
                if !self.warned_devices {
                    log_warn!(
                        "devices = {d} on the serial engine is degenerate (one device): \
                         use engine = \"parallel\" for a real device grid \
                         (recorded in PlanStats::degraded)"
                    );
                    self.warned_devices = true;
                }
                true
            }
            _ => false,
        }
    }

    pub fn with_defaults() -> Self {
        Self::new(FastTuckerConfig::default())
    }

    /// Batched-kernel configuration with a pinned single-fiber group cap.
    pub fn with_batch(batch: usize) -> Self {
        Self::new(FastTuckerConfig { batch: BatchSizing::Fixed(batch), ..Default::default() })
    }

    /// Planner-driven batching (cap + fiber tile chosen per dataset).
    pub fn with_auto_batch() -> Self {
        Self::new(FastTuckerConfig { batch: BatchSizing::Auto, ..Default::default() })
    }

    /// Plan statistics of the last batched epoch (None before the first
    /// epoch or on the scalar path).
    pub fn last_plan_stats(&self) -> Option<PlanStats> {
        self.last_plan_stats
    }

    /// Resolve this epoch's plan params (None = scalar kernel), caching
    /// the planner decision per workload fingerprint.
    fn resolve_params(
        &mut self,
        train: &SparseTensor,
        m: usize,
        order: usize,
        r_core: usize,
        j: usize,
    ) -> Option<PlanParams> {
        match self.config.batch {
            BatchSizing::Fixed(_) => self
                .config
                .batch
                .resolve(
                    train,
                    m,
                    order,
                    r_core,
                    j,
                    self.config.exactness,
                    self.config.lanes,
                    self.config.simd,
                    self.config.split,
                )
                .map(|p| p.with_wide_accum(self.config.wide_accum)),
            BatchSizing::Auto => {
                let key = (
                    train.revision(),
                    train.nnz(),
                    train.dims().to_vec(),
                    m,
                    order,
                    r_core,
                    j,
                    self.config.exactness,
                    self.config.lanes,
                    self.config.simd,
                    self.config.wide_accum,
                    self.config.split,
                );
                if let Some((cached_key, params)) = &self.auto_cache {
                    if *cached_key == key {
                        return Some(*params);
                    }
                }
                self.planner_rebuilds += 1;
                let params = self
                    .config
                    .batch
                    .resolve(
                        train,
                        m,
                        order,
                        r_core,
                        j,
                        self.config.exactness,
                        self.config.lanes,
                        self.config.simd,
                        self.config.split,
                    )
                    .expect("Auto sizing always resolves")
                    .with_wide_accum(self.config.wide_accum);
                self.auto_cache = Some((key, params));
                Some(params)
            }
        }
    }

    fn ensure_ws(&mut self, order: usize, r_core: usize, j: usize, params: Option<PlanParams>) {
        if let Some(p) = params {
            let cap = p.max_batch;
            let threads = planner::resolve_threads(self.config.threads, self.config.exactness);
            let stale = match &self.pool {
                Some(w) => w.shape() != (order, r_core, j, cap) || w.threads() != threads,
                None => true,
            };
            if stale {
                self.pool = Some(DispatchPool::new(threads, order, r_core, j, cap));
            }
        } else {
            let stale = match &self.ws {
                Some(w) => w.order != order || w.r_core != r_core || w.j != j,
                None => true,
            };
            if stale {
                self.ws = Some(Workspace::new(order, r_core, j));
            }
        }
    }
}

impl Decomposer for FastTucker {
    fn name(&self) -> &'static str {
        "fasttucker"
    }

    fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        epoch: usize,
        rng: &mut Rng,
    ) -> AlgoResult<EpochStats> {
        let (order, r_core, j) = match &model.core {
            CoreRepr::Kruskal(k) => (k.order(), k.rank(), k.j(0)),
            CoreRepr::Dense(_) => {
                return Err(AlgoError::core_mismatch("fasttucker", "Kruskal", "dense"))
            }
        };
        if self.config.layout == CoreLayout::Strided {
            let core = match &model.core {
                CoreRepr::Kruskal(k) => k,
                _ => unreachable!(),
            };
            self.strided = build_strided(core);
        }

        let h = self.config.hyper;
        let lr_f = h.lr_factor.at(epoch);
        let lr_c = h.lr_core.at(epoch);
        let sampler = Sampler::new(train.nnz());
        let m = ((train.nnz() as f64) * h.sample_frac).round().max(1.0) as usize;
        let params = self.resolve_params(train, m, order, r_core, j);
        self.ensure_ws(order, r_core, j, params);
        // The kernel consumes u32 ids; build them directly (same RNG draw
        // sequence as the historical usize path).
        let ids: Vec<u32> = if h.sample_frac >= 1.0 {
            let mut ids: Vec<u32> = (0..train.nnz() as u32).collect();
            rng.shuffle(&mut ids);
            ids
        } else {
            sampler.one_step(rng, m).into_iter().map(|k| k as u32).collect()
        };

        let t0 = Instant::now();
        let devices_degraded = self.devices_degraded();
        let use_batched = params.is_some();
        let stats = {
            let core = match &model.core {
                CoreRepr::Kruskal(k) => k,
                _ => unreachable!(),
            };
            if let Some(p) = params {
                let pool = self.pool.as_mut().unwrap();
                let plan =
                    BatchPlan::build_params_with_scratch(train, &ids, p, pool.plan_scratch_mut());
                let mut plan_stats = plan.stats();
                plan_stats.degraded |= devices_degraded;
                let shared = SharedFactors::new(&mut model.factors);
                // SAFETY (level 1, see `SharedFactors`): this engine
                // holds the only live reference to the factors for the
                // duration of the call — the whole plan's row set is
                // exclusively owned. Level 2 (intra-pool) is handled
                // inside `dispatch_plan` (exact coloring waves / atomic
                // hogwild access); the policy is the single shared
                // implementation the Latin workers use too.
                let st = unsafe {
                    dispatch_plan(
                        pool,
                        train,
                        &plan,
                        core,
                        &self.strided,
                        self.config.layout,
                        &shared,
                        lr_f,
                        h.lambda_factor,
                        h.update_core,
                        &mut plan_stats,
                    )
                };
                self.last_plan_stats = Some(plan_stats);
                pool.plan_scratch_mut().recycle(plan);
                st
            } else {
                scalar::run_ids(
                    self.ws.as_mut().unwrap(),
                    train,
                    &ids,
                    core,
                    &self.strided,
                    self.config.layout,
                    &mut model.factors,
                    lr_f,
                    h.lambda_factor,
                    h.update_core,
                    None,
                )
            }
        };
        let factor_secs = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        if h.update_core {
            let core = match &mut model.core {
                CoreRepr::Kruskal(k) => k,
                _ => unreachable!(),
            };
            if use_batched {
                let (grad, count) = self.pool.as_mut().unwrap().core_grad_mut();
                apply_core_grad_raw(grad, count, core, lr_c, h.lambda_core);
            } else {
                let (grad, count) = self.ws.as_mut().unwrap().core_grad_mut();
                apply_core_grad_raw(grad, count, core, lr_c, h.lambda_core);
            }
            if self.config.layout == CoreLayout::Strided {
                self.strided = build_strided(core);
            }
        }
        let core_secs = t1.elapsed().as_secs_f64();

        Ok(EpochStats { samples: stats.samples, factor_secs, core_secs })
    }

    fn updates_core(&self) -> bool {
        self.config.hyper.update_core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::kruskal::reconstruct::rmse;

    fn planted(seed: u64, order: usize) -> (crate::data::synth::Planted, PlantedSpec) {
        let spec = PlantedSpec {
            dims: vec![30; order],
            nnz: 4000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(seed);
        (planted_tucker(&mut rng, &spec), spec)
    }

    #[test]
    fn converges_on_planted_order3() {
        let (p, spec) = planted(1, 3);
        let mut rng = Rng::new(2);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
        algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
        let before = rmse(&model, &p.tensor);
        for epoch in 0..30 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.5 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn converges_on_planted_order4() {
        let spec = PlantedSpec {
            dims: vec![15; 4],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(3);
        let p = planted_tucker(&mut rng, &spec);
        let mut rng = Rng::new(4);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.05);
        algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.02);
        // Per-sample L2 decay compounds ~(1-lr·λ)^(nnz/dim) per epoch; at
        // order 4 the gradient signal is weak at init, so a large λ would
        // collapse the model to zero before it can fit. Use a small one.
        algo.config.hyper.lambda_factor = 1e-4;
        algo.config.hyper.lambda_core = 1e-4;
        let before = rmse(&model, &p.tensor);
        for epoch in 0..50 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.6 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn converges_with_batched_kernel() {
        // The fiber-batched path fits the same planted problem to the same
        // quality as the scalar path (sample order differs, accuracy must
        // not).
        let (p, spec) = planted(14, 3);
        let run = |batch: usize| {
            let mut rng = Rng::new(15);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut algo = FastTucker::with_batch(batch);
            algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
            algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
            let mut rng2 = Rng::new(16);
            for epoch in 0..20 {
                algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            rmse(&model, &p.tensor)
        };
        let scalar_rmse = run(0);
        for batch in [2usize, 16, 64] {
            let batched_rmse = run(batch);
            assert!(
                (batched_rmse - scalar_rmse).abs() < 0.3 * scalar_rmse.max(0.05),
                "batch {batch}: {batched_rmse} vs scalar {scalar_rmse}"
            );
        }
    }

    #[test]
    fn auto_batch_tiles_hollow_tensors_and_converges() {
        // A hollow planted workload (mean mode-0 fiber length < 4): the
        // planner must pick tile > 1, the tiled plan must lift mean group
        // length >= 4x over the single-fiber plan, and training quality
        // must match the scalar path. Trailing modes are wide (500) so
        // exact-mode collision splits don't mask the tiling lift; values
        // are ratings-style (clamped) so SGD on 3-sample fibers stays
        // stable at this lr.
        let spec = PlantedSpec {
            dims: vec![3000, 500, 500],
            nnz: 9000,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: Some((1.0, 5.0)),
        };
        let mut rng = Rng::new(30);
        let p = planted_tucker(&mut rng, &spec);
        let run = |batch: crate::kernel::BatchSizing| {
            let mut rng = Rng::new(31);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut algo = FastTucker::new(FastTuckerConfig {
                batch,
                ..Default::default()
            });
            algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
            algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
            let mut rng2 = Rng::new(32);
            for epoch in 0..20 {
                algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            (rmse(&model, &p.tensor), algo.last_plan_stats())
        };
        let (scalar_rmse, none_stats) = run(crate::kernel::BatchSizing::Fixed(0));
        assert!(none_stats.is_none());
        let (single_rmse, single_stats) = run(crate::kernel::BatchSizing::Fixed(64));
        let single_stats = single_stats.unwrap();
        assert!(
            single_stats.mean_group_len() < 4.0,
            "workload not hollow: {single_stats:?}"
        );
        let (auto_rmse, auto_stats) = run(crate::kernel::BatchSizing::Auto);
        let auto_stats = auto_stats.unwrap();
        assert!(auto_stats.tile > 1, "planner did not tile: {auto_stats:?}");
        assert!(
            auto_stats.mean_group_len() >= 4.0 * single_stats.mean_group_len(),
            "tiling lifted groups only {:.2} -> {:.2}",
            single_stats.mean_group_len(),
            auto_stats.mean_group_len()
        );
        for (name, r) in [("single", single_rmse), ("auto", auto_rmse)] {
            assert!(
                (r - scalar_rmse).abs() < 0.3 * scalar_rmse.max(0.05),
                "{name}: {r} vs scalar {scalar_rmse}"
            );
        }
    }

    #[test]
    fn relaxed_reaches_exact_quality() {
        // ISSUE 2 acceptance: hogwild plans must reach RMSE within 2% of
        // the exact batched path on a synthetic workload. Hollow tensor
        // with trailing modes tight enough (100) that relaxed groups
        // actually contain collisions (otherwise the test is vacuous);
        // ratings-style values keep the hollow-fiber SGD stable.
        let spec = PlantedSpec {
            dims: vec![2400, 100, 100],
            nnz: 7200,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: Some((1.0, 5.0)),
        };
        let mut rng = Rng::new(40);
        let p = planted_tucker(&mut rng, &spec);
        let run = |exactness: crate::kernel::Exactness, split: usize| {
            let mut rng = Rng::new(41);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut algo = FastTucker::new(FastTuckerConfig {
                batch: crate::kernel::BatchSizing::Auto,
                exactness,
                split,
                ..Default::default()
            });
            algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.01);
            algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.005);
            let mut rng2 = Rng::new(42);
            for epoch in 0..30 {
                algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            (rmse(&model, &p.tensor), algo.last_plan_stats().unwrap())
        };
        let (exact_rmse, exact_stats) = run(crate::kernel::Exactness::Exact, 1);
        let (relaxed_rmse, relaxed_stats) = run(crate::kernel::Exactness::Relaxed, 1);
        // Relaxed must actually have merged groups the exact mode split.
        assert!(
            relaxed_stats.mean_group_len() > exact_stats.mean_group_len(),
            "relaxed plans no longer than exact: {relaxed_stats:?} vs {exact_stats:?}"
        );
        assert!(
            relaxed_rmse <= exact_rmse * 1.02 + 1e-4,
            "relaxed RMSE {relaxed_rmse} not within 2% of exact {exact_rmse}"
        );
        // Relaxed + split-group refinement: sub-group cuts shorten the
        // hogwild groups (fewer stale reads), so quality stays within
        // the same 2% envelope of exact.
        let (relaxed_split_rmse, rs_stats) = run(crate::kernel::Exactness::Relaxed, 8);
        assert!(rs_stats.splits > 0, "split rule never engaged: {rs_stats:?}");
        assert!(
            rs_stats.mean_group_len() <= relaxed_stats.mean_group_len(),
            "split did not shorten relaxed groups: {rs_stats:?}"
        );
        assert!(
            relaxed_split_rmse <= exact_rmse * 1.02 + 1e-4,
            "relaxed+split RMSE {relaxed_split_rmse} not within 2% of exact {exact_rmse}"
        );
    }

    #[test]
    fn wide_accum_relaxed_stays_in_rmse_envelope() {
        // ISSUE 10 acceptance: f32 factor storage with f64 accumulation
        // on the relaxed path must land within the same 2% RMSE envelope
        // of the exact batched path that plain relaxed execution owes
        // (the `relaxed_reaches_exact_quality` contract).
        let spec = PlantedSpec {
            dims: vec![2400, 100, 100],
            nnz: 7200,
            j: 4,
            r_core: 4,
            noise: 0.05,
            clamp: Some((1.0, 5.0)),
        };
        let mut rng = Rng::new(45);
        let p = planted_tucker(&mut rng, &spec);
        let run = |exactness: crate::kernel::Exactness, wide: bool| {
            let mut rng = Rng::new(46);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut algo = FastTucker::new(FastTuckerConfig {
                batch: crate::kernel::BatchSizing::Auto,
                exactness,
                wide_accum: wide,
                ..Default::default()
            });
            algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.01);
            algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.005);
            let mut rng2 = Rng::new(47);
            for epoch in 0..30 {
                algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            rmse(&model, &p.tensor)
        };
        let exact_rmse = run(crate::kernel::Exactness::Exact, false);
        let wide_rmse = run(crate::kernel::Exactness::Relaxed, true);
        assert!(
            wide_rmse <= exact_rmse * 1.02 + 1e-4,
            "wide relaxed RMSE {wide_rmse} not within 2% of exact {exact_rmse}"
        );
    }

    #[test]
    fn in_group_threading_is_bitwise_neutral_on_serial_engine() {
        // ISSUE 4 tentpole, serial engine level: the intra-plan pool
        // (exact coloring waves + plan-order tape replay) must leave the
        // multi-epoch trained model — factors AND core — bitwise
        // identical to sequential execution. Hollow workload so the
        // planner tiles and the pays-off gate engages.
        let spec = PlantedSpec {
            dims: vec![2000, 400, 400],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut prng = Rng::new(81);
        let p = planted_tucker(&mut prng, &spec);
        let run = |threads: usize| {
            let mut rng = Rng::new(82);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut algo = FastTucker::new(FastTuckerConfig {
                batch: crate::kernel::BatchSizing::Auto,
                split: 8,
                threads: crate::kernel::ThreadCount::Fixed(threads),
                ..Default::default()
            });
            let mut rng2 = Rng::new(83);
            for epoch in 0..3 {
                algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            (model, algo.last_plan_stats().unwrap())
        };
        let (seq, st1) = run(1);
        let (pooled, st2) = run(2);
        assert_eq!(st1.threads, 1);
        assert_eq!(st2.threads, 2, "pool never engaged: {st2:?}");
        assert!(st2.waves > 0 && st2.wave_occupancy() >= 2.0, "{st2:?}");
        for n in 0..3 {
            for (a, b) in seq
                .factors
                .mat(n)
                .data()
                .iter()
                .zip(pooled.factors.mat(n).data().iter())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "mode {n} diverged under pooling");
            }
        }
        let (ck, cp) = match (&seq.core, &pooled.core) {
            (CoreRepr::Kruskal(a), CoreRepr::Kruskal(b)) => (a, b),
            _ => unreachable!(),
        };
        for n in 0..3 {
            for (a, b) in ck.factor(n).data().iter().zip(cp.factor(n).data().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "core mode {n} diverged (tape replay)");
            }
        }
    }

    #[test]
    fn serial_engine_degrades_fixed_multi_device_requests_loudly() {
        // ISSUE 5 satellite: the serial engine is one device — a fixed
        // devices > 1 must train normally but surface the degenerate
        // request through PlanStats::degraded (Auto stays clean).
        let (p, spec) = planted(21, 3);
        let run = |devices: DeviceCount| {
            let mut rng = Rng::new(22);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut algo = FastTucker::new(FastTuckerConfig {
                batch: crate::kernel::BatchSizing::Auto,
                devices,
                ..Default::default()
            });
            algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
            algo.last_plan_stats().unwrap()
        };
        assert!(run(DeviceCount::Fixed(4)).degraded);
        assert!(!run(DeviceCount::Fixed(1)).degraded);
        assert!(!run(DeviceCount::Auto).degraded);
    }

    #[test]
    fn strided_layout_matches_packed_numerically() {
        let (p, spec) = planted(5, 3);
        let make = |layout| {
            let mut rng = Rng::new(6);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut cfg = FastTuckerConfig::default();
            cfg.layout = layout;
            let mut algo = FastTucker::new(cfg);
            let mut rng2 = Rng::new(7);
            for epoch in 0..3 {
                algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            rmse(&model, &p.tensor)
        };
        let packed = make(CoreLayout::Packed);
        let strided = make(CoreLayout::Strided);
        assert!(
            (packed - strided).abs() < 1e-5,
            "layouts diverged: {packed} vs {strided}"
        );
    }

    #[test]
    fn matches_cutucker_with_equivalent_core() {
        // FastTucker with Kruskal core K and cuTucker with dense(K) compute
        // the same factor gradients: one epoch with the same sample order
        // and frozen cores must give identical factors (to f32 tolerance).
        let (p, spec) = planted(12, 3);
        let mut rng = Rng::new(13);
        let kmodel = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let kcore = match &kmodel.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let dmodel = TuckerModel {
            factors: kmodel.factors.clone(),
            core: CoreRepr::Dense(kcore.to_dense()),
        };

        let mut m1 = kmodel;
        let mut a1 = FastTucker::with_defaults();
        a1.config.hyper.update_core = false;
        let mut r1 = Rng::new(99);
        a1.train_epoch(&mut m1, &p.tensor, 0, &mut r1).unwrap();

        let mut m2 = dmodel;
        let mut a2 = crate::algo::CuTucker::with_defaults();
        a2.hyper.update_core = false;
        let mut r2 = Rng::new(99);
        a2.train_epoch(&mut m2, &p.tensor, 0, &mut r2).unwrap();

        for n in 0..3 {
            for (x, y) in m1
                .factors
                .mat(n)
                .data()
                .iter()
                .zip(m2.factors.mat(n).data().iter())
            {
                assert!((x - y).abs() < 2e-3, "mode {n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn factor_only_mode_leaves_core_untouched() {
        let (p, spec) = planted(8, 3);
        let mut rng = Rng::new(9);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let core_before = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.update_core = false;
        algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        let core_after = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        for n in 0..3 {
            assert_eq!(core_before.factor(n).data(), core_after.factor(n).data());
        }
    }

    #[test]
    fn sampled_epoch_visits_m_samples() {
        let (p, spec) = planted(10, 3);
        let mut rng = Rng::new(11);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.sample_frac = 0.25;
        let stats = algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        assert_eq!(stats.samples, 1000);
    }

    #[test]
    fn dense_core_reports_typed_error() {
        let (p, spec) = planted(17, 3);
        let mut rng = Rng::new(18);
        let mut model = TuckerModel::init_dense(&mut rng, &spec.dims, spec.j);
        let mut algo = FastTucker::with_defaults();
        let err = algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("fasttucker") && msg.contains("Kruskal"), "{msg}");
    }
}
