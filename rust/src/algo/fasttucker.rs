//! **FastTucker** — the paper's algorithm (Algorithm 1): stochastic SGD
//! over sampled nonzeros with the Kruskal-factored core and the Theorem-1/2
//! contraction reduction.
//!
//! The per-sample math lives in the shared kernel layer
//! ([`crate::kernel`]): this decomposer builds the epoch's sampling set Ψ
//! and dispatches it to either
//!
//! * the **scalar** kernel ([`crate::kernel::scalar`]) — one nonzero at a
//!   time in Ψ order (the paper's Algorithm 1 semantics), or
//! * the **batched** kernel ([`crate::kernel::batched`]) when
//!   [`FastTuckerConfig::batch`] ≥ 2 — Ψ is grouped by mode-1 fiber
//!   ([`crate::kernel::BatchPlan`]) and each group's shared factor row is
//!   staged once, with the contraction running over `batch × R_core`
//!   panels (cuFasterTucker's batching, arXiv:2210.06014). Bitwise
//!   identical to the scalar path over the same grouped order.
//!
//! The [`CoreLayout`] switch reproduces the paper's shared-vs-global-memory
//! ablation (Tables 8–12) on both paths.

use std::time::Instant;

use crate::algo::{AlgoError, AlgoResult, Decomposer, EpochStats, SgdHyper};
use crate::kernel::{apply_core_grad_raw, batched, scalar, BatchPlan, BatchWorkspace};
// Re-exported for compatibility: the contraction primitives historically
// lived in this module and are widely imported from here.
pub use crate::kernel::contract::{
    accumulate_core_grad, apply_core_grad, build_strided, contract_staged, CoreLayout,
    Workspace,
};

use crate::model::{CoreRepr, TuckerModel};
use crate::sched::Sampler;
use crate::tensor::SparseTensor;
use crate::util::Rng;

/// Configuration of the FastTucker decomposer.
#[derive(Clone, Copy, Debug)]
pub struct FastTuckerConfig {
    pub hyper: SgdHyper,
    pub layout: CoreLayout,
    /// Maximum batch-group length for the batched kernel. `0` or `1`
    /// selects the scalar kernel (Ψ processed in draw order, the legacy
    /// semantics); ≥ 2 selects fiber-batched execution.
    pub batch: usize,
}

impl Default for FastTuckerConfig {
    fn default() -> Self {
        FastTuckerConfig { hyper: SgdHyper::default(), layout: CoreLayout::Packed, batch: 0 }
    }
}

/// The FastTucker decomposer.
pub struct FastTucker {
    pub config: FastTuckerConfig,
    ws: Option<Workspace>,
    bws: Option<BatchWorkspace>,
    strided: Vec<Vec<f32>>,
}

impl FastTucker {
    pub fn new(config: FastTuckerConfig) -> Self {
        FastTucker { config, ws: None, bws: None, strided: Vec::new() }
    }

    pub fn with_defaults() -> Self {
        Self::new(FastTuckerConfig::default())
    }

    /// Batched-kernel configuration with group cap `batch`.
    pub fn with_batch(batch: usize) -> Self {
        Self::new(FastTuckerConfig { batch, ..Default::default() })
    }

    fn ensure_ws(&mut self, order: usize, r_core: usize, j: usize) {
        if self.config.batch >= 2 {
            let cap = self.config.batch;
            let stale = match &self.bws {
                Some(w) => w.shape() != (order, r_core, j, cap),
                None => true,
            };
            if stale {
                self.bws = Some(BatchWorkspace::new(order, r_core, j, cap));
            }
        } else {
            let stale = match &self.ws {
                Some(w) => w.order != order || w.r_core != r_core || w.j != j,
                None => true,
            };
            if stale {
                self.ws = Some(Workspace::new(order, r_core, j));
            }
        }
    }
}

impl Decomposer for FastTucker {
    fn name(&self) -> &'static str {
        "fasttucker"
    }

    fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        epoch: usize,
        rng: &mut Rng,
    ) -> AlgoResult<EpochStats> {
        let (order, r_core, j) = match &model.core {
            CoreRepr::Kruskal(k) => (k.order(), k.rank(), k.j(0)),
            CoreRepr::Dense(_) => {
                return Err(AlgoError::core_mismatch("fasttucker", "Kruskal", "dense"))
            }
        };
        self.ensure_ws(order, r_core, j);
        if self.config.layout == CoreLayout::Strided {
            let core = match &model.core {
                CoreRepr::Kruskal(k) => k,
                _ => unreachable!(),
            };
            self.strided = build_strided(core);
        }

        let h = self.config.hyper;
        let lr_f = h.lr_factor.at(epoch);
        let lr_c = h.lr_core.at(epoch);
        let sampler = Sampler::new(train.nnz());
        let m = ((train.nnz() as f64) * h.sample_frac).round().max(1.0) as usize;
        // The kernel consumes u32 ids; build them directly (same RNG draw
        // sequence as the historical usize path).
        let ids: Vec<u32> = if h.sample_frac >= 1.0 {
            let mut ids: Vec<u32> = (0..train.nnz() as u32).collect();
            rng.shuffle(&mut ids);
            ids
        } else {
            sampler.one_step(rng, m).into_iter().map(|k| k as u32).collect()
        };

        let t0 = Instant::now();
        let use_batched = self.config.batch >= 2;
        let stats = {
            let core = match &model.core {
                CoreRepr::Kruskal(k) => k,
                _ => unreachable!(),
            };
            if use_batched {
                let bws = self.bws.as_mut().unwrap();
                let plan =
                    BatchPlan::build_with_scratch(train, &ids, self.config.batch, bws.plan_scratch_mut());
                batched::run_plan(
                    bws,
                    train,
                    &plan,
                    core,
                    &self.strided,
                    self.config.layout,
                    &mut model.factors,
                    lr_f,
                    h.lambda_factor,
                    h.update_core,
                    None,
                )
            } else {
                scalar::run_ids(
                    self.ws.as_mut().unwrap(),
                    train,
                    &ids,
                    core,
                    &self.strided,
                    self.config.layout,
                    &mut model.factors,
                    lr_f,
                    h.lambda_factor,
                    h.update_core,
                    None,
                )
            }
        };
        let factor_secs = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        if h.update_core {
            let core = match &mut model.core {
                CoreRepr::Kruskal(k) => k,
                _ => unreachable!(),
            };
            if use_batched {
                let (grad, count) = self.bws.as_mut().unwrap().core_grad_mut();
                apply_core_grad_raw(grad, count, core, lr_c, h.lambda_core);
            } else {
                let (grad, count) = self.ws.as_mut().unwrap().core_grad_mut();
                apply_core_grad_raw(grad, count, core, lr_c, h.lambda_core);
            }
            if self.config.layout == CoreLayout::Strided {
                self.strided = build_strided(core);
            }
        }
        let core_secs = t1.elapsed().as_secs_f64();

        Ok(EpochStats { samples: stats.samples, factor_secs, core_secs })
    }

    fn updates_core(&self) -> bool {
        self.config.hyper.update_core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::kruskal::reconstruct::rmse;

    fn planted(seed: u64, order: usize) -> (crate::data::synth::Planted, PlantedSpec) {
        let spec = PlantedSpec {
            dims: vec![30; order],
            nnz: 4000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(seed);
        (planted_tucker(&mut rng, &spec), spec)
    }

    #[test]
    fn converges_on_planted_order3() {
        let (p, spec) = planted(1, 3);
        let mut rng = Rng::new(2);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
        algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
        let before = rmse(&model, &p.tensor);
        for epoch in 0..30 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.5 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn converges_on_planted_order4() {
        let spec = PlantedSpec {
            dims: vec![15; 4],
            nnz: 6000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(3);
        let p = planted_tucker(&mut rng, &spec);
        let mut rng = Rng::new(4);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.05);
        algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.02);
        // Per-sample L2 decay compounds ~(1-lr·λ)^(nnz/dim) per epoch; at
        // order 4 the gradient signal is weak at init, so a large λ would
        // collapse the model to zero before it can fit. Use a small one.
        algo.config.hyper.lambda_factor = 1e-4;
        algo.config.hyper.lambda_core = 1e-4;
        let before = rmse(&model, &p.tensor);
        for epoch in 0..50 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.6 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn converges_with_batched_kernel() {
        // The fiber-batched path fits the same planted problem to the same
        // quality as the scalar path (sample order differs, accuracy must
        // not).
        let (p, spec) = planted(14, 3);
        let run = |batch: usize| {
            let mut rng = Rng::new(15);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut algo = FastTucker::with_batch(batch);
            algo.config.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
            algo.config.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
            let mut rng2 = Rng::new(16);
            for epoch in 0..20 {
                algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            rmse(&model, &p.tensor)
        };
        let scalar_rmse = run(0);
        for batch in [2usize, 16, 64] {
            let batched_rmse = run(batch);
            assert!(
                (batched_rmse - scalar_rmse).abs() < 0.3 * scalar_rmse.max(0.05),
                "batch {batch}: {batched_rmse} vs scalar {scalar_rmse}"
            );
        }
    }

    #[test]
    fn strided_layout_matches_packed_numerically() {
        let (p, spec) = planted(5, 3);
        let make = |layout| {
            let mut rng = Rng::new(6);
            let mut model =
                TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
            let mut cfg = FastTuckerConfig::default();
            cfg.layout = layout;
            let mut algo = FastTucker::new(cfg);
            let mut rng2 = Rng::new(7);
            for epoch in 0..3 {
                algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng2).unwrap();
            }
            rmse(&model, &p.tensor)
        };
        let packed = make(CoreLayout::Packed);
        let strided = make(CoreLayout::Strided);
        assert!(
            (packed - strided).abs() < 1e-5,
            "layouts diverged: {packed} vs {strided}"
        );
    }

    #[test]
    fn matches_cutucker_with_equivalent_core() {
        // FastTucker with Kruskal core K and cuTucker with dense(K) compute
        // the same factor gradients: one epoch with the same sample order
        // and frozen cores must give identical factors (to f32 tolerance).
        let (p, spec) = planted(12, 3);
        let mut rng = Rng::new(13);
        let kmodel = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let kcore = match &kmodel.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let dmodel = TuckerModel {
            factors: kmodel.factors.clone(),
            core: CoreRepr::Dense(kcore.to_dense()),
        };

        let mut m1 = kmodel;
        let mut a1 = FastTucker::with_defaults();
        a1.config.hyper.update_core = false;
        let mut r1 = Rng::new(99);
        a1.train_epoch(&mut m1, &p.tensor, 0, &mut r1).unwrap();

        let mut m2 = dmodel;
        let mut a2 = crate::algo::CuTucker::with_defaults();
        a2.hyper.update_core = false;
        let mut r2 = Rng::new(99);
        a2.train_epoch(&mut m2, &p.tensor, 0, &mut r2).unwrap();

        for n in 0..3 {
            for (x, y) in m1
                .factors
                .mat(n)
                .data()
                .iter()
                .zip(m2.factors.mat(n).data().iter())
            {
                assert!((x - y).abs() < 2e-3, "mode {n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn factor_only_mode_leaves_core_untouched() {
        let (p, spec) = planted(8, 3);
        let mut rng = Rng::new(9);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let core_before = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.update_core = false;
        algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        let core_after = match &model.core {
            CoreRepr::Kruskal(k) => k.clone(),
            _ => unreachable!(),
        };
        for n in 0..3 {
            assert_eq!(core_before.factor(n).data(), core_after.factor(n).data());
        }
    }

    #[test]
    fn sampled_epoch_visits_m_samples() {
        let (p, spec) = planted(10, 3);
        let mut rng = Rng::new(11);
        let mut model = TuckerModel::init_kruskal(&mut rng, &spec.dims, spec.j, spec.r_core);
        let mut algo = FastTucker::with_defaults();
        algo.config.hyper.sample_frac = 0.25;
        let stats = algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        assert_eq!(stats.samples, 1000);
    }

    #[test]
    fn dense_core_reports_typed_error() {
        let (p, spec) = planted(17, 3);
        let mut rng = Rng::new(18);
        let mut model = TuckerModel::init_dense(&mut rng, &spec.dims, spec.j);
        let mut algo = FastTucker::with_defaults();
        let err = algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("fasttucker") && msg.contains("Kruskal"), "{msg}");
    }
}
