//! The decomposition algorithms: the paper's **cuFastTucker** plus the four
//! comparison methods of its evaluation (Section 6.3).
//!
//! | Algorithm   | Core repr | Update rule | Per-nonzero cost |
//! |-------------|-----------|-------------|------------------|
//! | FastTucker  | Kruskal   | SGD, Thm 1/2 reduction | O(N·R·J) |
//! | cuTucker    | dense     | SGD, direct contraction | O(N·J^N) |
//! | SGD_Tucker  | dense     | SGD, materialized Kronecker rows | O(N·J^N) + churn |
//! | P-Tucker    | dense     | row-wise ALS (normal equations) | O(J^N + J²) |
//! | Vest        | dense     | element-wise coordinate descent | O(J^N + J) |
//!
//! All expose the [`Decomposer`] trait so the trainer, the benches, and the
//! multi-device scheduler are algorithm-agnostic.

pub mod fasttucker;
pub mod cutucker;
pub mod sgd_tucker;
pub mod ptucker;
pub mod vest;

pub use cutucker::CuTucker;
pub use fasttucker::{CoreLayout, FastTucker, FastTuckerConfig};
pub use ptucker::PTucker;
pub use sgd_tucker::SgdTucker;
pub use vest::Vest;

use crate::model::TuckerModel;
use crate::tensor::SparseTensor;
use crate::util::Rng;

/// Timing/volume statistics for one training epoch, split the way the
/// paper's tables split them (factor-update time vs core-update time).
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochStats {
    /// Nonzeros visited this epoch (|Ψ| summed over rounds).
    pub samples: usize,
    /// Seconds spent updating factor matrices.
    pub factor_secs: f64,
    /// Seconds spent updating the core (0 for factor-only methods).
    pub core_secs: f64,
}

impl EpochStats {
    pub fn total_secs(&self) -> f64 {
        self.factor_secs + self.core_secs
    }

    pub fn merge(&mut self, other: &EpochStats) {
        self.samples += other.samples;
        self.factor_secs += other.factor_secs;
        self.core_secs += other.core_secs;
    }
}

/// Typed errors the decomposition algorithms report instead of aborting —
/// a misconfigured run (e.g. a TOML file pairing `algo = "vest"` with a
/// Kruskal-core model) surfaces as a usable message through
/// [`Decomposer::train_epoch`] and the trainer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AlgoError {
    /// The model's core representation does not match the algorithm's
    /// requirement (FastTucker needs Kruskal; the dense baselines need
    /// dense).
    CoreMismatch {
        algo: &'static str,
        expected: &'static str,
        found: &'static str,
    },
    /// The multi-device block/round geometry (`M^N` blocks, `M^{N-1}`
    /// Latin rounds) overflows `usize` or exceeds the block
    /// materialization budget
    /// ([`BlockPartition::MAX_BLOCKS`](crate::parallel::BlockPartition::MAX_BLOCKS))
    /// — previously a silent wrap in release builds (unchecked
    /// `usize::pow`) or an allocation abort; now surfaced before any
    /// allocation happens.
    PartitionOverflow { workers: usize, order: usize },
    /// The channel transport's exchange failed unrecoverably (retry
    /// budget exhausted, dead device, protocol violation, or invalid
    /// `FASTTUCKER_FAULT_*` configuration). The inner
    /// [`TransportError`](crate::parallel::TransportError) names the
    /// fault class; [`TransportError::DeviceDead`](crate::parallel::TransportError)
    /// is the elastic-recovery trigger — reload the last checkpoint into
    /// a freshly sharded engine and resume.
    Transport(crate::parallel::TransportError),
    /// A checkpoint file failed validation on load (truncated, corrupt
    /// checksum, impossible dimensions) — previously a panic or silently
    /// loaded garbage.
    CheckpointCorrupt { detail: String },
}

impl AlgoError {
    pub(crate) fn core_mismatch(
        algo: &'static str,
        expected: &'static str,
        found: &'static str,
    ) -> Self {
        AlgoError::CoreMismatch { algo, expected, found }
    }
}

impl std::fmt::Display for AlgoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AlgoError::CoreMismatch { algo, expected, found } => write!(
                f,
                "algorithm {algo} requires a {expected} core but the model holds a \
                 {found} core; initialize the model to match (see TuckerModel::init_*) \
                 or pick a matching `algo` in the run config"
            ),
            AlgoError::PartitionOverflow { workers, order } => write!(
                f,
                "multi-device geometry is unrepresentable: {workers} workers over an \
                 order-{order} tensor needs {workers}^{order} blocks \
                 ({workers}^{} Latin rounds), which overflows usize or exceeds the \
                 block budget; reduce `workers` or the tensor order",
                order.saturating_sub(1)
            ),
            AlgoError::Transport(e) => write!(
                f,
                "device exchange failed: {e}; the model may hold a partial epoch — \
                 resume from the last checkpoint"
            ),
            AlgoError::CheckpointCorrupt { detail } => write!(
                f,
                "checkpoint rejected: {detail}; the file is unusable — fall back to an \
                 older checkpoint or retrain"
            ),
        }
    }
}

impl std::error::Error for AlgoError {}

impl From<crate::parallel::TransportError> for AlgoError {
    fn from(e: crate::parallel::TransportError) -> Self {
        AlgoError::Transport(e)
    }
}

impl From<AlgoError> for crate::util::error::Error {
    fn from(e: AlgoError) -> Self {
        crate::util::error::Error::msg(e)
    }
}

/// Result type of the per-epoch training entry points.
pub type AlgoResult<T> = std::result::Result<T, AlgoError>;

/// A sparse-Tucker training algorithm.
pub trait Decomposer {
    /// Short identifier used in logs and bench tables.
    fn name(&self) -> &'static str;

    /// Run one epoch over `train`, mutating `model` in place. Returns
    /// [`AlgoError::CoreMismatch`] when the model's core representation
    /// does not fit the algorithm.
    fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        epoch: usize,
        rng: &mut Rng,
    ) -> AlgoResult<EpochStats>;

    /// Whether this method updates the core tensor (P-Tucker/Vest do not,
    /// matching the paper: "Some algorithms lack the update of the core
    /// tensor, and we only compare the update of the factor matrix").
    fn updates_core(&self) -> bool {
        true
    }
}

/// Shared hyperparameters for the SGD-family methods.
#[derive(Clone, Copy, Debug)]
pub struct SgdHyper {
    pub lr_factor: crate::sched::LrSchedule,
    pub lr_core: crate::sched::LrSchedule,
    pub lambda_factor: f32,
    pub lambda_core: f32,
    /// Fraction of nonzeros visited per epoch (|Ψ|/|Ω|); 1.0 = full pass.
    pub sample_frac: f64,
    /// Whether to update the core at all (paper Fig. 4's Factor vs
    /// Factor+Core ablation).
    pub update_core: bool,
}

impl Default for SgdHyper {
    fn default() -> Self {
        SgdHyper {
            lr_factor: crate::sched::LrSchedule::new(0.006, 0.05),
            lr_core: crate::sched::LrSchedule::new(0.0045, 0.1),
            lambda_factor: 0.01,
            lambda_core: 0.01,
            sample_frac: 1.0,
            update_core: true,
        }
    }
}
