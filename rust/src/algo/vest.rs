//! **Vest** (Park et al.) — element-wise coordinate descent (CCD) for
//! sparse Tucker. For each mode `n`, each row `i`, each coordinate `j`,
//! the closed-form single-coordinate minimizer is
//!
//! `a_ij = ( Σ_{nz∈Ω_i} (r_nz + a_ij·d_j) · d_j ) / ( λ + Σ_{nz} d_j² )`
//!
//! with residuals `r_nz = x - x̂` maintained incrementally across the
//! row's coordinate sweep. Each nonzero's coefficient vector `d = D^(n)`
//! goes through the dense core (`O(J^N)` each; no Kruskal reduction).
//! Like P-Tucker, the factor-update path is the one the paper times
//! (Table 13); core updates are not part of this baseline's sweep.

use std::time::Instant;

use crate::algo::{AlgoError, AlgoResult, Decomposer, EpochStats};
use crate::model::{CoreRepr, TuckerModel};
use crate::tensor::{ModeSlices, SparseTensor};
use crate::util::linalg::dot;
use crate::util::Rng;

/// The Vest (CCD) decomposer.
pub struct Vest {
    pub lambda: f32,
    slices: Vec<ModeSlices>,
    slices_for: Option<(usize, usize)>,
    /// Row scratch: per-nonzero coefficient matrix (|Ω_i| × J) + residuals.
    dmat: Vec<f32>,
    resid: Vec<f32>,
}

impl Vest {
    pub fn new(lambda: f32) -> Self {
        Vest {
            lambda,
            slices: Vec::new(),
            slices_for: None,
            dmat: Vec::new(),
            resid: Vec::new(),
        }
    }

    pub fn with_defaults() -> Self {
        Self::new(0.01)
    }

    fn ensure_slices(&mut self, train: &SparseTensor) {
        let fp = (train.nnz(), train.order());
        if self.slices_for != Some(fp) {
            self.slices = (0..train.order())
                .map(|n| ModeSlices::build(train, n))
                .collect();
            self.slices_for = Some(fp);
        }
    }
}

impl Decomposer for Vest {
    fn name(&self) -> &'static str {
        "vest"
    }

    fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        _epoch: usize,
        _rng: &mut Rng,
    ) -> AlgoResult<EpochStats> {
        let core = match &model.core {
            CoreRepr::Dense(c) => c.clone(),
            CoreRepr::Kruskal(_) => {
                return Err(AlgoError::core_mismatch("vest", "dense", "Kruskal"))
            }
        };
        self.ensure_slices(train);
        let order = model.order();
        let j = model.rank();
        let t0 = Instant::now();

        let mut visited = 0usize;
        for n in 0..order {
            // Clone the slices handle to appease the borrow checker (the
            // ModeSlices are read-only during the sweep).
            let slices = self.slices[n].clone();
            for i in slices.nonempty_rows() {
                let nzs = slices.slice(i);
                let rn = nzs.len();
                self.dmat.resize(rn * j, 0.0);
                self.resid.resize(rn, 0.0);

                // Build the row's coefficient matrix and residuals.
                for (t, &nz) in nzs.iter().enumerate() {
                    let coords = train.index(nz as usize);
                    let x = train.value(nz as usize);
                    let drow = &mut self.dmat[t * j..(t + 1) * j];
                    core.mode_coeff(&model.factors, coords, n, drow);
                    let xhat = dot(model.factors.row(n, i), drow);
                    self.resid[t] = x - xhat;
                    visited += 1;
                }

                // CCD over the row's J coordinates.
                for jj in 0..j {
                    let a_old = model.factors.row(n, i)[jj];
                    let mut num = 0.0f32;
                    let mut den = self.lambda;
                    for t in 0..rn {
                        let djt = self.dmat[t * j + jj];
                        num += (self.resid[t] + a_old * djt) * djt;
                        den += djt * djt;
                    }
                    let a_new = num / den;
                    let delta = a_new - a_old;
                    if delta != 0.0 {
                        model.factors.row_mut(n, i)[jj] = a_new;
                        for t in 0..rn {
                            self.resid[t] -= delta * self.dmat[t * j + jj];
                        }
                    }
                }
            }
        }

        Ok(EpochStats {
            samples: visited,
            factor_secs: t0.elapsed().as_secs_f64(),
            core_secs: 0.0,
        })
    }

    fn updates_core(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::kruskal::reconstruct::rmse;

    #[test]
    fn ccd_descends_on_planted() {
        let spec = PlantedSpec {
            dims: vec![15, 15, 15],
            nnz: 3000,
            j: 3,
            r_core: 3,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(1);
        let p = planted_tucker(&mut rng, &spec);
        let mut model = TuckerModel {
            factors: crate::model::factors::FactorMatrices::random(
                &mut rng,
                &spec.dims,
                spec.j,
                0.5,
            ),
            core: CoreRepr::Dense(p.truth_core.to_dense()),
        };
        let mut algo = Vest::with_defaults();
        let before = rmse(&model, &p.tensor);
        for epoch in 0..8 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.4 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn each_coordinate_update_never_increases_row_loss() {
        // CCD's defining invariant: the row objective is monotone
        // non-increasing across an epoch (exact coordinate minimization).
        let spec = PlantedSpec {
            dims: vec![10, 10, 10],
            nnz: 800,
            j: 3,
            r_core: 3,
            noise: 0.2,
            clamp: None,
        };
        let mut rng = Rng::new(2);
        let p = planted_tucker(&mut rng, &spec);
        let mut model = TuckerModel::init_dense(&mut rng, &spec.dims, spec.j);
        // λ ≈ 0 so the RMSE *is* the CCD objective (up to f32 rounding).
        let mut algo = Vest::new(1e-9);
        let mut prev = f64::INFINITY;
        for epoch in 0..4 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
            let cur = rmse(&model, &p.tensor);
            assert!(
                cur <= prev * 1.001 + 1e-9,
                "epoch {epoch}: rmse increased {prev} -> {cur}"
            );
            prev = cur;
        }
    }

    #[test]
    fn does_not_touch_core() {
        let spec = PlantedSpec {
            dims: vec![8, 8, 8],
            nnz: 200,
            j: 2,
            r_core: 2,
            noise: 0.1,
            clamp: None,
        };
        let mut rng = Rng::new(3);
        let p = planted_tucker(&mut rng, &spec);
        let mut model = TuckerModel::init_dense(&mut rng, &spec.dims, spec.j);
        let core_before = match &model.core {
            CoreRepr::Dense(c) => c.data().to_vec(),
            _ => unreachable!(),
        };
        let mut algo = Vest::with_defaults();
        algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap();
        let core_after = match &model.core {
            CoreRepr::Dense(c) => c.data().to_vec(),
            _ => unreachable!(),
        };
        assert_eq!(core_before, core_after);
    }
}
