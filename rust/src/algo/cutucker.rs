//! **cuTucker** — the paper's ablation baseline (Section 6): the same
//! one-step stochastic SGD strategy as FastTucker but with an **explicit
//! dense core** and no Theorem-1/2 reduction, so every per-sample update
//! pays the exponential `O(N·J^N)` contraction the Kruskal strategy
//! removes.
//!
//! Implementation notes: a single pass over the `∏J` core entries computes,
//! via per-entry prefix/suffix products over modes, all N mode-coefficient
//! vectors `D^(n)` *and* the core-gradient direction `Π_n a^(n)_{i_n,j_n}`
//! simultaneously — the tightest honest implementation of the dense path
//! (the exponential term is irreducible; we only avoid gratuitous passes).

use std::time::Instant;

use crate::algo::{AlgoError, AlgoResult, Decomposer, EpochStats, SgdHyper};
use crate::kruskal::DenseCore;
use crate::model::factors::FactorMatrices;
use crate::model::{CoreRepr, TuckerModel};
use crate::sched::Sampler;
use crate::tensor::{indexing, SparseTensor};
use crate::util::linalg::{dot, scale_axpy};
use crate::util::Rng;

/// Scratch for the dense-core SGD step.
struct DenseWs {
    order: usize,
    j: usize,
    core_len: usize,
    /// Precomputed multi-index table: `coords_tbl[idx*order + n]`.
    coords_tbl: Vec<u32>,
    /// Per-mode coefficient vectors `D^(n)`, flattened `[n][j]`.
    d: Vec<f32>,
    /// Staged factor rows for the current sample, `[n][j]`.
    a_stage: Vec<f32>,
    /// Accumulated core gradient over the epoch.
    core_grad: Vec<f32>,
    core_grad_count: usize,
}

impl DenseWs {
    fn new(order: usize, j: usize) -> Self {
        let core_len = j.pow(order as u32);
        let dims = vec![j; order];
        let mut coords_tbl = vec![0u32; core_len * order];
        let mut coords = vec![0u32; order];
        for idx in 0..core_len {
            indexing::dense_coords(idx, &dims, &mut coords);
            coords_tbl[idx * order..(idx + 1) * order].copy_from_slice(&coords);
        }
        DenseWs {
            order,
            j,
            core_len,
            coords_tbl,
            d: vec![0.0; order * j],
            a_stage: vec![0.0; order * j],
            core_grad: vec![0.0; core_len],
            core_grad_count: 0,
        }
    }
}

/// The cuTucker decomposer.
pub struct CuTucker {
    pub hyper: SgdHyper,
    ws: Option<DenseWs>,
}

impl CuTucker {
    pub fn new(hyper: SgdHyper) -> Self {
        CuTucker { hyper, ws: None }
    }

    pub fn with_defaults() -> Self {
        Self::new(SgdHyper::default())
    }

    fn ensure_ws(&mut self, order: usize, j: usize) {
        let stale = match &self.ws {
            Some(w) => w.order != order || w.j != j,
            None => true,
        };
        if stale {
            self.ws = Some(DenseWs::new(order, j));
        }
    }

    /// One SGD sample through the dense core; returns the residual. The
    /// core-representation check happens once per epoch in `train_epoch`
    /// (typed [`AlgoError`]), not per sample.
    fn step_sample(
        ws: &mut DenseWs,
        core: &DenseCore,
        factors: &mut FactorMatrices,
        coords: &[u32],
        x: f32,
        lr_f: f32,
        lam_f: f32,
        accumulate_core: bool,
    ) -> f32 {
        let order = ws.order;
        let j = ws.j;
        let core_data = core.data();

        // Gather the factor-row values for this sample's coordinates so the
        // core sweep reads from a compact `order × J` staging buffer.
        // (On the GPU these rows sit in shared memory.)
        for n in 0..order {
            ws.a_stage[n * j..(n + 1) * j]
                .copy_from_slice(factors.row(n, coords[n] as usize));
        }
        let a_stage = &ws.a_stage;

        // Single exponential sweep: D^(n)[j_n] += g·Π_{m≠n} a_m and the
        // full product for x̂ (folded into D via mode 0 afterwards).
        ws.d.fill(0.0);
        let mut pre = [0.0f32; 16]; // order <= 10 supported; headroom.
        let mut suf = [0.0f32; 16];
        debug_assert!(order < 15);
        for idx in 0..ws.core_len {
            let g = core_data[idx];
            let cc = &ws.coords_tbl[idx * order..(idx + 1) * order];
            // prefix/suffix over modes of a-values.
            pre[0] = 1.0;
            for n in 0..order {
                pre[n + 1] = pre[n] * a_stage[n * j + cc[n] as usize];
            }
            suf[order] = 1.0;
            for n in (0..order).rev() {
                suf[n] = suf[n + 1] * a_stage[n * j + cc[n] as usize];
            }
            for n in 0..order {
                ws.d[n * j + cc[n] as usize] += g * pre[n] * suf[n + 1];
            }
        }

        let xhat = dot(&a_stage[0..j], &ws.d[0..j]);
        let e = xhat - x;

        // Core gradient direction: Π_n a^(n)[j_n] (pre-update rows).
        if accumulate_core {
            for idx in 0..ws.core_len {
                let cc = &ws.coords_tbl[idx * order..(idx + 1) * order];
                let mut prod = e;
                for n in 0..order {
                    prod *= a_stage[n * j + cc[n] as usize];
                }
                ws.core_grad[idx] += prod;
            }
            ws.core_grad_count += 1;
        }

        // Factor SGD (identical rule to FastTucker's Eq. 13).
        for n in 0..order {
            let d_n = &ws.d[n * j..(n + 1) * j];
            let row = factors.row_mut(n, coords[n] as usize);
            scale_axpy(1.0 - lr_f * lam_f, -lr_f * e, d_n, row);
        }
        e
    }
}

impl Decomposer for CuTucker {
    fn name(&self) -> &'static str {
        "cutucker"
    }

    fn train_epoch(
        &mut self,
        model: &mut TuckerModel,
        train: &SparseTensor,
        epoch: usize,
        rng: &mut Rng,
    ) -> AlgoResult<EpochStats> {
        if matches!(&model.core, CoreRepr::Kruskal(_)) {
            return Err(AlgoError::core_mismatch("cutucker", "dense", "Kruskal"));
        }
        let (order, j) = (model.order(), model.rank());
        self.ensure_ws(order, j);
        let h = self.hyper;
        let lr_f = h.lr_factor.at(epoch);
        let lr_c = h.lr_core.at(epoch);

        let sampler = Sampler::new(train.nnz());
        let m = ((train.nnz() as f64) * h.sample_frac).round().max(1.0) as usize;
        let psi = if h.sample_frac >= 1.0 {
            let mut ids: Vec<usize> = (0..train.nnz()).collect();
            rng.shuffle(&mut ids);
            ids
        } else {
            sampler.one_step(rng, m)
        };

        let ws = self.ws.as_mut().unwrap();
        let t0 = Instant::now();
        {
            let core = match &model.core {
                CoreRepr::Dense(c) => c,
                CoreRepr::Kruskal(_) => unreachable!(),
            };
            for &k in &psi {
                Self::step_sample(
                    ws,
                    core,
                    &mut model.factors,
                    train.index(k),
                    train.value(k),
                    lr_f,
                    h.lambda_factor,
                    h.update_core,
                );
            }
        }
        let factor_secs = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        if h.update_core && ws.core_grad_count > 0 {
            let mcount = ws.core_grad_count as f32;
            let core = match &mut model.core {
                CoreRepr::Dense(c) => c,
                CoreRepr::Kruskal(_) => unreachable!(),
            };
            for (gv, &grad) in core.data_mut().iter_mut().zip(ws.core_grad.iter()) {
                *gv = (1.0 - lr_c * h.lambda_core) * *gv - lr_c * grad / mcount;
            }
            ws.core_grad.fill(0.0);
            ws.core_grad_count = 0;
        }
        let core_secs = t1.elapsed().as_secs_f64();
        Ok(EpochStats { samples: psi.len(), factor_secs, core_secs })
    }

    fn updates_core(&self) -> bool {
        self.hyper.update_core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{planted_tucker, PlantedSpec};
    use crate::kruskal::reconstruct::rmse;

    #[test]
    fn converges_on_planted() {
        let spec = PlantedSpec {
            dims: vec![25, 25, 25],
            nnz: 3000,
            j: 4,
            r_core: 4,
            noise: 0.01,
            clamp: None,
        };
        let mut rng = Rng::new(1);
        let p = planted_tucker(&mut rng, &spec);
        let mut model = TuckerModel::init_dense(&mut rng, &spec.dims, spec.j);
        let mut algo = CuTucker::with_defaults();
        algo.hyper.lr_factor = crate::sched::LrSchedule::constant(0.02);
        algo.hyper.lr_core = crate::sched::LrSchedule::constant(0.01);
        let before = rmse(&model, &p.tensor);
        for epoch in 0..30 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.6 * before, "rmse {before} -> {after}");
    }

    #[test]
    fn kruskal_core_reports_typed_error() {
        let mut rng = Rng::new(9);
        let p = planted_tucker(
            &mut rng,
            &PlantedSpec {
                dims: vec![8, 8, 8],
                nnz: 100,
                j: 2,
                r_core: 2,
                noise: 0.1,
                clamp: None,
            },
        );
        let mut model = TuckerModel::init_kruskal(&mut rng, &[8, 8, 8], 2, 2);
        let mut algo = CuTucker::with_defaults();
        let err = algo.train_epoch(&mut model, &p.tensor, 0, &mut rng).unwrap_err();
        assert!(err.to_string().contains("cutucker"), "{err}");
    }

    #[test]
    fn mode_coeff_matches_dense_core_oracle() {
        // The fused per-entry prefix/suffix D computation must equal
        // DenseCore::mode_coeff.
        let mut rng = Rng::new(2);
        let dims = [8usize, 9, 10];
        let model = TuckerModel::init_dense(&mut rng, &dims, 3);
        let core = match &model.core {
            CoreRepr::Dense(c) => c.clone(),
            _ => unreachable!(),
        };
        let coords = [5u32, 6, 7];
        let mut ws = DenseWs::new(3, 3);
        let mut m2 = model.clone();
        // Run with lr 0 so factors are unchanged; inspect ws.d.
        CuTucker::step_sample(&mut ws, &core, &mut m2.factors, &coords, 0.0, 0.0, 0.0, false);
        for n in 0..3 {
            let mut want = vec![0.0f32; 3];
            core.mode_coeff(&model.factors, &coords, n, &mut want);
            for jj in 0..3 {
                assert!(
                    (ws.d[n * 3 + jj] - want[jj]).abs() < 1e-4,
                    "mode {n} j {jj}: {} vs {}",
                    ws.d[n * 3 + jj],
                    want[jj]
                );
            }
        }
    }

    #[test]
    fn core_update_reduces_error_alone() {
        // With factors frozen at truth and a perturbed core, core updates
        // alone should shrink RMSE.
        let spec = PlantedSpec {
            dims: vec![15, 15, 15],
            nnz: 2000,
            j: 3,
            r_core: 3,
            noise: 0.0,
            clamp: None,
        };
        let mut rng = Rng::new(3);
        let p = planted_tucker(&mut rng, &spec);
        let dense_truth = p.truth_core.to_dense();
        let mut noisy = dense_truth.clone();
        for v in noisy.data_mut() {
            *v += 0.3 * rng.normal();
        }
        let mut model = TuckerModel {
            factors: p.truth_factors.clone(),
            core: CoreRepr::Dense(noisy),
        };
        let mut algo = CuTucker::with_defaults();
        algo.hyper.lr_factor = crate::sched::LrSchedule::constant(0.0); // freeze factors
        // The core update is one averaged full-batch step per epoch, so it
        // tolerates (and needs) a much larger rate than per-sample SGD.
        algo.hyper.lr_core = crate::sched::LrSchedule::constant(1.0);
        algo.hyper.lambda_core = 1e-6;
        let before = rmse(&model, &p.tensor);
        for epoch in 0..40 {
            algo.train_epoch(&mut model, &p.tensor, epoch, &mut rng).unwrap();
        }
        let after = rmse(&model, &p.tensor);
        assert!(after < 0.5 * before, "rmse {before} -> {after}");
    }
}
