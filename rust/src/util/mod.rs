//! Small self-contained utilities: PRNG, logging, dense linear algebra,
//! and a miniature property-testing harness.
//!
//! These exist because the build is fully offline: the only external crates
//! available are `xla` and `anyhow`, so the usual `rand`/`log`/`proptest`
//! stack is replaced by focused in-tree implementations.

pub mod rng;
pub mod logger;
pub mod linalg;
pub mod propcheck;

pub use rng::Rng;
