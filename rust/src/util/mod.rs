//! Small self-contained utilities: PRNG, logging, dense linear algebra,
//! error handling, and a miniature property-testing harness.
//!
//! These exist because the build is fully offline with **zero external
//! crates**: the usual `rand`/`log`/`proptest`/`anyhow` stack is replaced
//! by focused in-tree implementations.

pub mod element;
pub mod error;
pub mod hash;
pub mod rng;
pub mod logger;
pub mod linalg;
pub mod propcheck;

pub use element::Element;
pub use error::{Context, Error, Result};
pub use hash::fnv1a64;
pub use rng::Rng;
