//! Minimal error handling for the fully-offline build (no `anyhow`).
//!
//! Provides the small slice of the `anyhow` API the crate uses — a
//! string-backed [`Error`], the [`anyhow!`]/[`bail!`] macros, a [`Result`]
//! alias, and the [`Context`] extension trait for `Result`/`Option` — so
//! error-handling call sites read identically to the upstream idiom while
//! the build stays dependency-free.

use std::fmt;

/// A boxed, human-readable error with an optional context chain.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::msg(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error { msg: s.to_string() }
    }
}

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors, `anyhow`-style.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

// Re-export the macros under this module's path so call sites can
// `use crate::util::error::{anyhow, bail}` like they would with the
// upstream crate.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("broke with code {}", 7)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke with code 7");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
