//! Miniature property-testing harness (offline build: no `proptest`).
//!
//! A property is a closure receiving a per-case [`Rng`]; the harness runs it
//! for many seeded cases and, on panic, reports the failing case seed so the
//! failure replays deterministically with [`replay`].
//!
//! ```no_run
//! // (no_run: doctest binaries bypass the crate's rpath to the PJRT libs)
//! use fasttucker::util::propcheck::forall;
//! forall("addition commutes", 64, |rng| {
//!     let (a, b) = (rng.gen_range(1000) as i64, rng.gen_range(1000) as i64);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::Rng;

/// The base-seed override variable consulted by [`forall`].
pub const PROP_SEED_VAR: &str = "FASTTUCKER_PROP_SEED";

const DEFAULT_PROP_SEED: u64 = 0xFA57_7C4E_5EED;

/// Parse a `FASTTUCKER_PROP_SEED` value: an unsigned 64-bit integer,
/// decimal or `0x`-prefixed hex (the harness reports replay seeds in
/// hex, so pasting one back verbatim must work). Pure so it is testable
/// without mutating process-global environment state.
fn parse_seed(raw: &str) -> Result<u64, String> {
    let s = raw.trim();
    let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => s.parse::<u64>(),
    };
    parsed.map_err(|_| {
        format!("expected an unsigned 64-bit integer (decimal or 0x-hex), got {raw:?}")
    })
}

/// Base seed; combined with the case index so each case is independent but
/// reproducible. Override with `FASTTUCKER_PROP_SEED` to explore new cases.
///
/// Regression (ISSUE 10 satellite): a malformed override used to fall
/// back **silently** to the default seed — a run the operator believed
/// was exploring `FASTTUCKER_PROP_SEED=deadbeef` was actually re-running
/// the stock cases. Malformed or non-unicode values now abort loudly
/// with the offending value, matching the `FASTTUCKER_FAULT_*`
/// validation precedent.
fn base_seed() -> u64 {
    match std::env::var(PROP_SEED_VAR) {
        Ok(raw) => parse_seed(&raw).unwrap_or_else(|e| {
            panic!("invalid {PROP_SEED_VAR}: {e}");
        }),
        Err(std::env::VarError::NotPresent) => DEFAULT_PROP_SEED,
        Err(std::env::VarError::NotUnicode(os)) => {
            panic!("invalid {PROP_SEED_VAR}: value {os:?} is not valid unicode");
        }
    }
}

/// Run `cases` seeded cases of `prop`. Panics with the failing seed attached.
pub fn forall<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum symmetric", 32, |rng| {
            let a = rng.gen_range(100);
            let b = rng.gen_range(100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let res = std::panic::catch_unwind(|| {
            forall("always fails", 4, |_| panic!("boom"));
        });
        let err = res.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
    }

    #[test]
    fn seed_parser_accepts_decimal_and_hex() {
        assert_eq!(parse_seed("12345"), Ok(12345));
        assert_eq!(parse_seed("  42 "), Ok(42));
        assert_eq!(parse_seed("0xFA57"), Ok(0xFA57));
        assert_eq!(parse_seed("0Xdeadbeef"), Ok(0xDEAD_BEEF));
        assert_eq!(parse_seed(&format!("{:#x}", u64::MAX)), Ok(u64::MAX));
    }

    #[test]
    fn seed_parser_rejects_garbage_with_the_offending_value() {
        // Regression: these all used to silently fall back to the default
        // base seed; they must now produce an error naming the bad value.
        for bad in ["", "deadbeef", "-1", "1.5", "0x", "0xZZ", "12three"] {
            let err = parse_seed(bad).unwrap_err();
            assert!(err.contains(&format!("{bad:?}")), "{bad}: {err}");
        }
        // One past u64::MAX overflows rather than wrapping.
        assert!(parse_seed("18446744073709551616").is_err());
    }

    #[test]
    fn replay_reproduces_case() {
        // The same seed must always feed the property identical randomness.
        let mut first = None;
        for _ in 0..2 {
            replay(0x1234, |rng| {
                let v = rng.next_u64();
                if let Some(f) = first {
                    assert_eq!(f, v);
                } else {
                    first = Some(v);
                }
            });
        }
    }
}
