//! Miniature property-testing harness (offline build: no `proptest`).
//!
//! A property is a closure receiving a per-case [`Rng`]; the harness runs it
//! for many seeded cases and, on panic, reports the failing case seed so the
//! failure replays deterministically with [`replay`].
//!
//! ```no_run
//! // (no_run: doctest binaries bypass the crate's rpath to the PJRT libs)
//! use fasttucker::util::propcheck::forall;
//! forall("addition commutes", 64, |rng| {
//!     let (a, b) = (rng.gen_range(1000) as i64, rng.gen_range(1000) as i64);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::Rng;

/// Base seed; combined with the case index so each case is independent but
/// reproducible. Override with `FASTTUCKER_PROP_SEED` to explore new cases.
fn base_seed() -> u64 {
    std::env::var("FASTTUCKER_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xFA57_7C4E_5EED)
}

/// Run `cases` seeded cases of `prop`. Panics with the failing seed attached.
pub fn forall<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum symmetric", 32, |rng| {
            let a = rng.gen_range(100);
            let b = rng.gen_range(100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let res = std::panic::catch_unwind(|| {
            forall("always fails", 4, |_| panic!("boom"));
        });
        let err = res.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
    }

    #[test]
    fn replay_reproduces_case() {
        // The same seed must always feed the property identical randomness.
        let mut first = None;
        for _ in 0..2 {
            replay(0x1234, |rng| {
                let v = rng.next_u64();
                if let Some(f) = first {
                    assert_eq!(f, v);
                } else {
                    first = Some(v);
                }
            });
        }
    }
}
