//! The sealed [`Element`] scalar abstraction (ISSUE 10): one trait
//! genericizing the value type of [`SparseTensor`] and the storage type
//! of the dense factor containers ([`Matrix`] / `FactorMatrices`), so
//! the **input precision** and the **factor precision** are independent
//! axes.
//!
//! The paper's mixed-precision recipe stores everything that is *large*
//! (the nonzero stream, the factor matrices) in f32 and accumulates
//! everything that is *numerically delicate* (the Theorem-1/2
//! contraction reductions) in f64 — [`Element::Wide`] names that
//! accumulator type per storage type. The relaxed-mode wide path
//! (`PlanParams::wide_accum`) is the consumer: f32 storage, f64
//! accumulation, narrowing exactly once at the SGD write-back.
//!
//! The trait is **sealed** (only `f32` and `f64` implement it): the hot
//! kernels monomorphize over a closed set, every implementation is a
//! plain IEEE-754 type with the conversions below total and lossless in
//! the directions the kernels use, and downstream crates cannot smuggle
//! in a type that breaks the bitwise contracts pinned by
//! `tests/properties.rs`.
//!
//! [`SparseTensor`]: crate::tensor::SparseTensor
//! [`Matrix`]: crate::model::factors::Matrix

use std::fmt::Debug;
use std::ops::{Add, Mul, Sub};

mod sealed {
    /// Seals [`super::Element`]: the kernel layer's numeric contracts are
    /// only audited for the two IEEE-754 types below.
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
}

/// A scalar the tensor/factor containers can store and the kernels can
/// reduce over. See the module docs for why it is sealed.
pub trait Element:
    sealed::Sealed
    + Copy
    + Debug
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
{
    /// The accumulator type wide enough to sum many `Self` products
    /// without catastrophic rounding (f64 for both storage types — for
    /// f64 storage the accumulator is already as wide as it gets).
    type Wide: Element;

    /// Additive identity (`vec![Self::ZERO; n]` is the generic
    /// `vec![0.0; n]`).
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;

    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;

    /// Widen into the accumulator type (lossless for both impls).
    #[inline]
    fn widen(self) -> Self::Wide {
        Self::Wide::from_f64(self.to_f64())
    }
}

impl Element for f32 {
    type Wide = f64;
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;

    #[inline]
    fn from_f32(v: f32) -> f32 {
        v
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    #[inline]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl Element for f64 {
    type Wide = f64;
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;

    #[inline]
    fn from_f32(v: f32) -> f64 {
        v as f64
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self as f32
    }
    #[inline]
    fn from_f64(v: f64) -> f64 {
        v
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identities_and_conversions() {
        assert_eq!(f32::ZERO + f32::ONE, 1.0f32);
        assert_eq!(f64::ZERO + f64::ONE, 1.0f64);
        assert_eq!(f32::from_f64(0.5), 0.5f32);
        assert_eq!(f64::from_f32(0.5), 0.5f64);
        assert_eq!(1.5f32.widen(), 1.5f64);
        assert_eq!(1.5f64.widen(), 1.5f64);
    }

    #[test]
    fn widening_f32_is_lossless() {
        // Every f32 (including subnormals and the classic 0.1 rounding
        // victim) round-trips exactly through its Wide type.
        for v in [0.1f32, f32::MIN_POSITIVE, 1.0e-45, 3.4e38, -7.25] {
            let w = v.widen();
            assert_eq!(f32::from_f64(w), v);
        }
    }

    fn generic_sum<E: Element>(xs: &[E]) -> E::Wide {
        let mut acc = <E::Wide>::ZERO;
        for &x in xs {
            acc = acc + x.widen();
        }
        acc
    }

    #[test]
    fn generic_reduction_monomorphizes_for_both_impls() {
        assert_eq!(generic_sum(&[1.0f32, 2.0, 3.0]), 6.0f64);
        assert_eq!(generic_sum(&[1.0f64, 2.0, 3.0]), 6.0f64);
    }
}
