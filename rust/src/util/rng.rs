//! Deterministic, seedable PRNG (xoshiro256++ core, splitmix64 seeding).
//!
//! Every stochastic component of the library (sampling, initialization,
//! synthetic data) takes an explicit [`Rng`] so experiments are exactly
//! reproducible from a seed recorded in the config.

/// xoshiro256++ PRNG. Not cryptographic; fast and statistically solid,
/// which is all SGD sampling needs.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed the generator; any u64 is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.uniform_f64()).max(1e-12);
        let u2 = self.uniform_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)`.
    ///
    /// Uses Floyd's algorithm: O(k) expected time, no O(n) allocation,
    /// so the one-step sampling set Ψ of the paper stays cheap even when
    /// `n` = |Ω| is hundreds of millions.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for n in [1usize, 2, 3, 10, 1000, 1 << 40] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.gen_range(7)] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(17);
        for (n, k) in [(10, 10), (100, 7), (1000, 500), (5, 0)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(23);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
