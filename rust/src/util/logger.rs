//! Minimal leveled logger (offline build: no `log`/`env_logger`).
//!
//! Level is taken from `FASTTUCKER_LOG` (`error|warn|info|debug|trace`),
//! defaulting to `info`. Output goes to stderr so experiment drivers can
//! pipe structured results on stdout.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: Once = Once::new();

fn init() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("FASTTUCKER_LOG") {
            let lvl = match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "info" => Level::Info,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            };
            LEVEL.store(lvl as u8, Ordering::Relaxed);
        }
    });
}

/// Set the level programmatically (overrides the env var).
pub fn set_level(level: Level) {
    init();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// True if `level` is currently enabled.
pub fn enabled(level: Level) -> bool {
    init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Core log routine; prefer the `log_*!` macros.
pub fn log(level: Level, module: &str, args: std::fmt::Arguments) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {args}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info,
                                  module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn,
                                  module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug,
                                  module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error,
                                  module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
