//! FNV-1a 64-bit hashing — the crate's integrity checksum.
//!
//! Used by the transport frame format
//! ([`crate::parallel::transport`]) and the checkpoint file format
//! ([`crate::model::checkpoint`], format version 2) to detect
//! corruption. FNV-1a is not cryptographic — it guards against
//! bit-flips, truncation, and framing bugs, not adversaries — but it is
//! tiny, dependency-free, and has a published reference the constants
//! below can be checked against.

/// FNV-1a offset basis (64-bit).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime (64-bit).
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Hash `bytes` with 64-bit FNV-1a.
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_published_fnv1a_vectors() {
        // Reference vectors from the FNV specification (Noll's tables).
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn detects_single_bit_flips() {
        let base = b"the quick brown fox jumps over the lazy dog".to_vec();
        let h0 = fnv1a64(&base);
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut flipped = base.clone();
                flipped[i] ^= 1 << bit;
                assert_ne!(fnv1a64(&flipped), h0, "flip at byte {i} bit {bit} undetected");
            }
        }
    }

    #[test]
    fn detects_truncation() {
        let base = vec![7u8; 64];
        let h0 = fnv1a64(&base);
        for cut in 0..64 {
            assert_ne!(fnv1a64(&base[..cut]), h0, "truncation to {cut} undetected");
        }
    }
}
