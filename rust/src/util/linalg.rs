//! Small dense linear algebra used by the algorithms: dot/axpy kernels for
//! the SGD hot path and a Cholesky solver for P-Tucker's J×J normal
//! equations. Everything operates on flat `&[f32]` slices to keep the hot
//! loops allocation-free.

/// Dot product. Written over `zip` so the optimizer sees equal trip counts
/// and elides bounds checks; 4-lane partial sums give LLVM an associative
/// reduction to vectorize without `-ffast-math` (perf pass iteration 1,
/// see EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let mut chunks_a = a.chunks_exact(4);
    let mut chunks_b = b.chunks_exact(4);
    for (ca, cb) in (&mut chunks_a).zip(&mut chunks_b) {
        acc[0] += ca[0] * cb[0];
        acc[1] += ca[1] * cb[1];
        acc[2] += ca[2] * cb[2];
        acc[3] += ca[3] * cb[3];
    }
    let mut tail = 0.0f32;
    for (&x, &y) in chunks_a.remainder().iter().zip(chunks_b.remainder()) {
        tail += x * y;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// `y = beta*y + alpha*x` (general update used by SGD with regularization:
/// `a <- a - lr*(e*gs + lam*a)` is `scale_axpy(1.0 - lr*lam, -lr*e, gs, a)`).
#[inline]
pub fn scale_axpy(beta: f32, alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        *yi = beta * *yi + alpha * xi;
    }
}

/// Row-major matrix–vector product `out = M x` (`M` is `rows × cols`),
/// register-blocked 4 rows at a time so each loaded `x` element feeds four
/// accumulators (perf pass iteration 2 — the Thm-1/2 `c = B^(n) a` step).
#[inline]
pub fn matvec_rowmajor(m: &[f32], rows: usize, cols: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(x.len(), cols);
    debug_assert_eq!(out.len(), rows);
    let mut r = 0;
    while r + 4 <= rows {
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let r0 = &m[r * cols..(r + 1) * cols];
        let r1 = &m[(r + 1) * cols..(r + 2) * cols];
        let r2 = &m[(r + 2) * cols..(r + 3) * cols];
        let r3 = &m[(r + 3) * cols..(r + 4) * cols];
        for j in 0..cols {
            let xj = x[j];
            a0 += r0[j] * xj;
            a1 += r1[j] * xj;
            a2 += r2[j] * xj;
            a3 += r3[j] * xj;
        }
        out[r] = a0;
        out[r + 1] = a1;
        out[r + 2] = a2;
        out[r + 3] = a3;
        r += 4;
    }
    while r < rows {
        out[r] = dot(&m[r * cols..(r + 1) * cols], x);
        r += 1;
    }
}

/// Weighted row sum `out = Σ_r w[r] · M[r, :]` (`M` row-major
/// `rows × cols`), blocked 4 rows per pass over `out` (perf pass
/// iteration 3 — the Thm-1/2 `GS^(n) = Σ_r w_r b_r^(n)` step).
#[inline]
pub fn weighted_rowsum(m: &[f32], rows: usize, cols: usize, w: &[f32], out: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(w.len(), rows);
    debug_assert_eq!(out.len(), cols);
    out.fill(0.0);
    let mut r = 0;
    while r + 4 <= rows {
        let (w0, w1, w2, w3) = (w[r], w[r + 1], w[r + 2], w[r + 3]);
        let r0 = &m[r * cols..(r + 1) * cols];
        let r1 = &m[(r + 1) * cols..(r + 2) * cols];
        let r2 = &m[(r + 2) * cols..(r + 3) * cols];
        let r3 = &m[(r + 3) * cols..(r + 4) * cols];
        for j in 0..cols {
            out[j] += w0 * r0[j] + w1 * r1[j] + w2 * r2[j] + w3 * r3[j];
        }
        r += 4;
    }
    while r < rows {
        axpy(w[r], &m[r * cols..(r + 1) * cols], out);
        r += 1;
    }
}

/// Wide-accumulation dot product: f32 operands, every product and the
/// running sum in f64 (ISSUE 10 `wide_accum` path). Plain sequential
/// association — the wide path has no bitwise contract to pin, so no
/// lane blocking.
#[inline]
pub fn dot_wide(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc += (x as f64) * (y as f64);
    }
    acc
}

/// Wide-accumulation row-major matvec `out = M x`: f32 matrix and
/// vector, f64 accumulators and output (ISSUE 10 `wide_accum` step 1).
#[inline]
pub fn matvec_rowmajor_wide(m: &[f32], rows: usize, cols: usize, x: &[f32], out: &mut [f64]) {
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(x.len(), cols);
    debug_assert_eq!(out.len(), rows);
    for r in 0..rows {
        out[r] = dot_wide(&m[r * cols..(r + 1) * cols], x);
    }
}

/// Wide-accumulation weighted row sum `out = Σ_r w[r] · M[r, :]`: f32
/// matrix, f64 weights and accumulators (ISSUE 10 `wide_accum` step 3).
#[inline]
pub fn weighted_rowsum_wide(m: &[f32], rows: usize, cols: usize, w: &[f64], out: &mut [f64]) {
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!(w.len(), rows);
    debug_assert_eq!(out.len(), cols);
    out.fill(0.0);
    for r in 0..rows {
        let wr = w[r];
        let row = &m[r * cols..(r + 1) * cols];
        for (o, &v) in out.iter_mut().zip(row.iter()) {
            *o += wr * (v as f64);
        }
    }
}

/// Dense symmetric positive-definite solve via Cholesky: `A x = b`,
/// `A` row-major `n×n` (only the lower triangle is read). Returns `None`
/// if the matrix is not (numerically) positive definite.
///
/// Used by the P-Tucker baseline: `(H^T H + λI) a = H^T x` with `n = J`
/// (a few tens), so an unblocked Cholesky is the right tool.
pub fn cholesky_solve(a: &[f32], b: &[f32], n: usize) -> Option<Vec<f32>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    // Factor: L lower-triangular with A = L L^T.
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j] as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    // Forward solve L y = b.
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    // Back solve L^T x = y.
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in (i + 1)..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    Some(x.into_iter().map(|v| v as f32).collect())
}

/// Rank-1 symmetric update `A += alpha * v v^T` (row-major, full matrix).
#[inline]
pub fn syr(alpha: f32, v: &[f32], a: &mut [f32]) {
    let n = v.len();
    debug_assert_eq!(a.len(), n * n);
    for i in 0..n {
        let avi = alpha * v[i];
        let row = &mut a[i * n..(i + 1) * n];
        for j in 0..n {
            row[j] += avi * v[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
    }

    #[test]
    fn scale_axpy_matches_sgd_form() {
        // a <- a - lr*(e*gs + lam*a) == (1-lr*lam)*a - lr*e * gs
        let (lr, lam, e) = (0.1f32, 0.01f32, 0.5f32);
        let gs = [1.0f32, -2.0];
        let mut a = [2.0f32, 3.0];
        let manual: Vec<f32> = a
            .iter()
            .zip(gs.iter())
            .map(|(&ai, &gi)| ai - lr * (e * gi + lam * ai))
            .collect();
        scale_axpy(1.0 - lr * lam, -lr * e, &gs, &mut a);
        assert!((a[0] - manual[0]).abs() < 1e-6);
        assert!((a[1] - manual[1]).abs() < 1e-6);
    }

    #[test]
    fn cholesky_solves_spd_system() {
        let mut rng = Rng::new(3);
        let n = 12;
        // Build SPD A = M M^T + I, random x, b = A x; check recovery.
        let m: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s;
            }
        }
        let x_true: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut b = vec![0.0f32; n];
        for i in 0..n {
            b[i] = dot(&a[i * n..(i + 1) * n], &x_true);
        }
        let x = cholesky_solve(&a, &b, n).unwrap();
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-2, "{} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        // [[0, 1], [1, 0]] is indefinite.
        let a = vec![0.0, 1.0, 1.0, 0.0];
        assert!(cholesky_solve(&a, &[1.0, 1.0], 2).is_none());
    }

    #[test]
    fn syr_accumulates_outer_product() {
        let mut a = vec![0.0f32; 4];
        syr(2.0, &[1.0, 3.0], &mut a);
        assert_eq!(a, vec![2.0, 6.0, 6.0, 18.0]);
    }
}
