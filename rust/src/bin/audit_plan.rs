//! `audit_plan` — run the first-principles disjointness auditor
//! (`fasttucker::analysis::audit`) over a synthetic workload, ad hoc:
//! build a plan + sub-group coloring and a device grid + Latin schedule
//! for the requested geometry, audit all three contract levels, print
//! the report, and exit nonzero on any violation.
//!
//! ```text
//! audit_plan [--dims 512,64,48] [--nnz 4000] [--workers 4] [--devices 2]
//!            [--cap 64] [--tile 8] [--split 2] [--seed 7]
//! ```
//!
//! This is the same checker the `strict-audit` cargo feature wires into
//! the engines; the binary exists so a geometry can be audited without
//! running a training epoch (e.g. when bisecting a scheduler change).

use fasttucker::analysis::{audit_coloring, audit_schedule_and_grid, waves_of, AuditReport};
use fasttucker::data::synth;
use fasttucker::kernel::{BatchPlan, PlanParams};
use fasttucker::parallel::{DeviceCount, DeviceGrid, LatinSchedule};
use fasttucker::util::Rng;

struct Opts {
    dims: Vec<usize>,
    nnz: usize,
    workers: usize,
    devices: usize,
    cap: usize,
    tile: usize,
    split: usize,
    seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            dims: vec![512, 64, 48],
            nnz: 4000,
            workers: 4,
            devices: 2,
            cap: 64,
            tile: 8,
            split: 2,
            seed: 7,
        }
    }
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts::default();
    let mut args = std::env::args().skip(1);
    while let Some(flag) = args.next() {
        if flag == "--help" || flag == "-h" {
            println!(
                "audit_plan [--dims D0,D1,...] [--nnz N] [--workers M] [--devices D] \
                 [--cap C] [--tile T] [--split S] [--seed K]"
            );
            std::process::exit(0);
        }
        let value = args
            .next()
            .ok_or_else(|| format!("{flag} expects a value"))?;
        let usize_of = |v: &str| {
            v.parse::<usize>()
                .map_err(|_| format!("{flag} expects an integer, got {v:?}"))
        };
        match flag.as_str() {
            "--dims" => {
                opts.dims = value
                    .split(',')
                    .map(|p| {
                        p.trim()
                            .parse::<usize>()
                            .map_err(|_| format!("--dims expects integers, got {p:?}"))
                    })
                    .collect::<Result<_, _>>()?;
                if opts.dims.len() < 2 || opts.dims.iter().any(|&d| d == 0) {
                    return Err(format!("--dims needs >= 2 nonzero extents, got {value:?}"));
                }
            }
            "--nnz" => opts.nnz = usize_of(&value)?.max(1),
            "--workers" => opts.workers = usize_of(&value)?.max(1),
            "--devices" => opts.devices = usize_of(&value)?.max(1),
            "--cap" => opts.cap = usize_of(&value)?.max(1),
            "--tile" => opts.tile = usize_of(&value)?.max(1),
            "--split" => opts.split = usize_of(&value)?.max(1),
            "--seed" => opts.seed = usize_of(&value)? as u64,
            other => return Err(format!("unknown flag {other:?} (see --help)")),
        }
    }
    Ok(opts)
}

fn main() {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };

    let mut rng = Rng::new(opts.seed);
    let tensor = synth::random_uniform(&mut rng, &opts.dims, opts.nnz, 1.0, 5.0);
    println!(
        "workload: dims={:?} nnz={} workers={} devices={} cap={} tile={} split={} seed={}",
        opts.dims,
        tensor.nnz(),
        opts.workers,
        opts.devices,
        opts.cap,
        opts.tile,
        opts.split,
        opts.seed
    );

    let mut report = AuditReport::default();

    // Level 2: exact-mode sub-group coloring over the full-tensor plan.
    let ids: Vec<u32> = (0..tensor.nnz() as u32).collect();
    let params = PlanParams::tiled(opts.cap, opts.tile).with_split(opts.split);
    let plan = BatchPlan::build_params(&tensor, &ids, params);
    let coloring = plan.color_subgroups(&tensor);
    let waves = waves_of(&coloring);
    let r = audit_coloring(&tensor, &plan, &waves);
    println!(
        "coloring: {} sub-groups in {} waves — {}",
        plan.n_groups(),
        waves.len(),
        if r.ok() { "clean" } else { "VIOLATIONS" }
    );
    report.merge(r);

    // Levels 0 + 1: device grid and the Latin schedule it coarsens.
    let schedule = match LatinSchedule::try_new(opts.workers, opts.dims.len()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: cannot build Latin schedule: {e}");
            std::process::exit(2);
        }
    };
    let grid = match DeviceGrid::try_new(DeviceCount::Fixed(opts.devices), opts.workers, &opts.dims) {
        Ok(g) => g,
        Err(e) => {
            eprintln!("error: cannot build device grid: {e}");
            std::process::exit(2);
        }
    };
    let r = audit_schedule_and_grid(&grid, &schedule, &tensor);
    println!(
        "grid/schedule: {} devices x {} workers, {} rounds — {}",
        grid.devices(),
        opts.workers,
        schedule.rounds(),
        if r.ok() { "clean" } else { "VIOLATIONS" }
    );
    report.merge(r);

    print!("{report}");
    if !report.ok() {
        std::process::exit(1);
    }
}
