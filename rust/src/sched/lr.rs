//! The NOMAD-style dynamic learning rate the paper adopts (Section 6.1):
//! `γ_t = α / (1 + β · t^{1.5})`, with separate (α, β, λ) triples for the
//! factor matrices and the core factors (paper Tables 6–7).

/// One learning-rate schedule.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    /// Initial learning rate α.
    pub alpha: f32,
    /// Decay coefficient β.
    pub beta: f32,
}

impl LrSchedule {
    pub fn new(alpha: f32, beta: f32) -> Self {
        // alpha == 0 is allowed: it freezes the corresponding update
        // (used by ablations that train only factors or only the core).
        assert!(alpha >= 0.0 && beta >= 0.0);
        LrSchedule { alpha, beta }
    }

    /// Fixed rate (β = 0).
    pub fn constant(alpha: f32) -> Self {
        Self::new(alpha, 0.0)
    }

    /// Rate at iteration `t` (0-based; the paper's t counts epochs).
    #[inline]
    pub fn at(&self, t: usize) -> f32 {
        self.alpha / (1.0 + self.beta * (t as f32).powf(1.5))
    }

    /// Paper Table 7 defaults for cuFastTucker factor updates at rank J.
    pub fn paper_factor_default(j: usize) -> Self {
        let alpha = match j {
            0..=4 => 0.009,
            5..=8 => 0.006,
            9..=16 => 0.0036,
            _ => 0.002,
        };
        LrSchedule::new(alpha, 0.05)
    }

    /// Paper Table 7 defaults for cuFastTucker core updates at rank J.
    pub fn paper_core_default(j: usize) -> Self {
        let alpha = match j {
            0..=8 => 0.0045,
            9..=16 => 0.0035,
            _ => 0.0025,
        };
        LrSchedule::new(alpha, 0.1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decays_monotonically() {
        let s = LrSchedule::new(0.01, 0.1);
        let mut prev = f32::INFINITY;
        for t in 0..50 {
            let lr = s.at(t);
            assert!(lr > 0.0 && lr <= prev);
            prev = lr;
        }
    }

    #[test]
    fn t0_is_alpha() {
        let s = LrSchedule::new(0.02, 0.3);
        assert!((s.at(0) - 0.02).abs() < 1e-9);
    }

    #[test]
    fn constant_never_decays() {
        let s = LrSchedule::constant(0.01);
        assert_eq!(s.at(0), s.at(1000));
    }

    #[test]
    fn matches_paper_formula() {
        let s = LrSchedule::new(0.0045, 0.1);
        let t = 9usize;
        let want = 0.0045 / (1.0 + 0.1 * (9.0f32).powf(1.5));
        assert!((s.at(t) - want).abs() < 1e-9);
    }

    #[test]
    fn paper_defaults_positive() {
        for j in [4, 8, 16, 32] {
            assert!(LrSchedule::paper_factor_default(j).at(0) > 0.0);
            assert!(LrSchedule::paper_core_default(j).at(0) > 0.0);
        }
    }
}
