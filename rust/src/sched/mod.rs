//! SGD machinery: the dynamic learning-rate schedule and the one-step
//! sampling of the paper's stochastic strategy.

pub mod lr;
pub mod sampler;

pub use lr::LrSchedule;
pub use sampler::Sampler;
