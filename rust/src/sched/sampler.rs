//! The paper's one-step sampling set Ψ: each SGD round draws a subset of
//! nonzero ids whose gradient approximates the full-Ω gradient.
//!
//! Two modes:
//! * [`Sampler::epoch_shuffle`] — a shuffled pass over all nonzeros split
//!   into batches (classic epoch semantics; what the convergence figures
//!   use so "epoch" matches the paper's x-axis).
//! * [`Sampler::one_step`] — draw |Ψ| ids with replacement per round (the
//!   paper's Definition 6 stochastic strategy; cheapest).

use crate::util::Rng;

/// Stateless sampling helpers over `0..nnz`.
pub struct Sampler {
    nnz: usize,
}

impl Sampler {
    pub fn new(nnz: usize) -> Self {
        assert!(nnz > 0, "cannot sample from an empty tensor");
        Sampler { nnz }
    }

    /// Draw a one-step sampling set Ψ of size `m` (with replacement, as
    /// SGD theory assumes; duplicates are legal and rare when m ≪ nnz).
    pub fn one_step(&self, rng: &mut Rng, m: usize) -> Vec<usize> {
        (0..m).map(|_| rng.gen_range(self.nnz)).collect()
    }

    /// A full shuffled epoch, yielded as contiguous batches of `batch`
    /// (last batch may be short).
    pub fn epoch_shuffle(&self, rng: &mut Rng, batch: usize) -> Vec<Vec<usize>> {
        assert!(batch > 0);
        let mut ids: Vec<usize> = (0..self.nnz).collect();
        rng.shuffle(&mut ids);
        ids.chunks(batch).map(|c| c.to_vec()).collect()
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn one_step_in_range() {
        let s = Sampler::new(100);
        let mut rng = Rng::new(1);
        let psi = s.one_step(&mut rng, 1000);
        assert_eq!(psi.len(), 1000);
        assert!(psi.iter().all(|&i| i < 100));
    }

    #[test]
    fn one_step_covers_support() {
        // With m >> nnz, essentially every id should appear.
        let s = Sampler::new(20);
        let mut rng = Rng::new(2);
        let psi = s.one_step(&mut rng, 2000);
        let seen: std::collections::HashSet<_> = psi.into_iter().collect();
        assert_eq!(seen.len(), 20);
    }

    #[test]
    fn epoch_shuffle_is_permutation() {
        forall("epoch shuffle partitions ids", 16, |rng| {
            let nnz = 1 + rng.gen_range(500);
            let batch = 1 + rng.gen_range(64);
            let s = Sampler::new(nnz);
            let batches = s.epoch_shuffle(rng, batch);
            let mut all: Vec<usize> = batches.into_iter().flatten().collect();
            all.sort_unstable();
            assert_eq!(all, (0..nnz).collect::<Vec<_>>());
        });
    }

    #[test]
    fn epoch_batch_sizes() {
        let s = Sampler::new(10);
        let mut rng = Rng::new(3);
        let batches = s.epoch_shuffle(&mut rng, 4);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].len(), 4);
        assert_eq!(batches[2].len(), 2);
    }

    #[test]
    #[should_panic]
    fn empty_tensor_panics() {
        Sampler::new(0);
    }
}
