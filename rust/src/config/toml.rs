//! A deliberately small TOML-subset parser: `[sections]`, `key = value`
//! pairs, `#` comments. Values: quoted strings, booleans, integers,
//! floats. Enough for experiment configs without pulling in serde (which
//! the offline build cannot).

use std::collections::HashMap;

use crate::util::error::{anyhow, bail, Result};

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
}

impl TomlValue {
    /// Human-readable value kind for error messages of keys that accept
    /// several types (e.g. `batch = "auto"` vs `batch = 64`).
    pub fn type_name(&self) -> &'static str {
        match self {
            TomlValue::Str(_) => "string",
            TomlValue::Bool(_) => "bool",
            TomlValue::Int(_) => "integer",
            TomlValue::Float(_) => "float",
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }
}

/// A parsed document: `(section, key) -> value`, root section is `""`.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: HashMap<(String, String), TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected `key = value`", lineno + 1);
            };
            let key = line[..eq].trim().to_string();
            let val = line[eq + 1..].trim();
            if key.is_empty() || val.is_empty() {
                bail!("line {}: empty key or value", lineno + 1);
            }
            let value = parse_value(val)
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            entries.insert((section.clone(), key), value);
        }
        Ok(TomlDoc { entries })
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            bail!("unterminated string: {s:?}");
        }
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value: {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_types() {
        let doc = TomlDoc::parse(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\n[sec]\ne = false\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&TomlValue::Float(2.5)));
        assert_eq!(doc.get("", "c"), Some(&TomlValue::Str("hi".into())));
        assert_eq!(doc.get("", "d"), Some(&TomlValue::Bool(true)));
        assert_eq!(doc.get("sec", "e"), Some(&TomlValue::Bool(false)));
        assert_eq!(doc.get("sec", "a"), None);
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = TomlDoc::parse("# c\n\na = 1 # trailing\ns = \"x # y\"\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("", "s"), Some(&TomlValue::Str("x # y".into())));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("just words").is_err());
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"unterminated").is_err());
    }

    #[test]
    fn negative_numbers() {
        let doc = TomlDoc::parse("a = -3\nb = -0.5\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_f64().unwrap(), -3.0);
        assert!(doc.get("", "a").unwrap().as_usize().is_err());
        assert_eq!(doc.get("", "b").unwrap().as_f64().unwrap(), -0.5);
    }

    #[test]
    fn value_conversions() {
        assert!(TomlValue::Int(5).as_usize().unwrap() == 5);
        assert!(TomlValue::Str("x".into()).as_bool().is_err());
        assert!(TomlValue::Bool(true).as_f64().is_err());
    }

    #[test]
    fn type_names() {
        assert_eq!(TomlValue::Str("x".into()).type_name(), "string");
        assert_eq!(TomlValue::Bool(true).type_name(), "bool");
        assert_eq!(TomlValue::Int(1).type_name(), "integer");
        assert_eq!(TomlValue::Float(1.5).type_name(), "float");
    }
}
