//! Config system: a minimal TOML-subset parser (offline build: no serde)
//! plus the typed [`TrainConfig`] the launcher consumes.

pub mod toml;

pub use toml::{TomlDoc, TomlValue};

use crate::util::error::{anyhow, bail, Context, Result};

use crate::algo::SgdHyper;
use crate::kernel::{BatchSizing, Exactness, Lanes, SimdLevel, ThreadCount};
use crate::parallel::{DeviceCount, PrefetchMode, TransportKind};
use crate::sched::LrSchedule;

/// Which algorithm to train with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoKind {
    FastTucker,
    CuTucker,
    SgdTucker,
    PTucker,
    Vest,
}

impl AlgoKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fasttucker" => AlgoKind::FastTucker,
            "cutucker" => AlgoKind::CuTucker,
            "sgd_tucker" | "sgdtucker" => AlgoKind::SgdTucker,
            "ptucker" | "p-tucker" => AlgoKind::PTucker,
            "vest" => AlgoKind::Vest,
            other => bail!("unknown algorithm {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::FastTucker => "fasttucker",
            AlgoKind::CuTucker => "cutucker",
            AlgoKind::SgdTucker => "sgd_tucker",
            AlgoKind::PTucker => "ptucker",
            AlgoKind::Vest => "vest",
        }
    }
}

/// Which compute engine executes the SGD steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust order-N engine.
    Native,
    /// AOT JAX/Pallas artifacts through PJRT (order-3, fixed shapes).
    Pjrt,
    /// Multi-device simulation (native math, M workers).
    Parallel,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "native" => EngineKind::Native,
            "pjrt" => EngineKind::Pjrt,
            "parallel" => EngineKind::Parallel,
            other => bail!("unknown engine {other:?}"),
        })
    }
}

/// Full training configuration (file + CLI overrides).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub dataset: String,
    pub scale: f64,
    pub algo: AlgoKind,
    pub engine: EngineKind,
    pub j: usize,
    pub r_core: usize,
    pub epochs: usize,
    pub workers: usize,
    pub seed: u64,
    pub test_frac: f64,
    pub hyper: SgdHyper,
    pub artifacts_dir: String,
    pub checkpoint: Option<String>,
    pub eval_every: usize,
    /// Evaluation thread count for the parallel RMSE/MAE pass (≥ 1).
    /// TOML: `eval_threads = 4`.
    pub eval_threads: usize,
    /// Cap on the PJRT artifact batch size (None = planner-sized from
    /// the training nnz when the launcher knows it, else the largest
    /// compiled variant).
    pub pjrt_batch_cap: Option<usize>,
    /// Batch sizing for the fasttucker engines: `Auto` (planner cost
    /// model) or `Fixed(n)` (`0`/`1` = scalar kernel). TOML:
    /// `batch = "auto"` or `batch = 64`.
    pub batch: BatchSizing,
    /// Batched-plan collision semantics. TOML: `exactness = "exact"` or
    /// `"relaxed"` (hogwild).
    pub exactness: Exactness,
    /// Panel-microkernel lane width. TOML: `lanes = "auto"` (planner
    /// picks from `R_core`) or `lanes = 4` / `lanes = 8`.
    pub lanes: Lanes,
    /// Panel-microkernel instruction set. TOML: `simd = "auto"` (runtime
    /// detection, overridable via `FASTTUCKER_SIMD`), `"scalar"`,
    /// `"v128"` (SSE2/NEON), or `"v256"` (AVX2, clamped to the host's
    /// best level). Every level is bitwise-identical — a pure
    /// performance knob.
    pub simd: SimdLevel,
    /// Mixed-precision accumulation. TOML: `wide_accum = true` stores
    /// factors in f32 but accumulates contractions in f64 on the relaxed
    /// path (sequential; no panel kernels). Needs
    /// `exactness = "relaxed"` — exact mode owes a bitwise match to the
    /// f32 scalar oracle, which f64 accumulation breaks by design.
    pub wide_accum: bool,
    /// Split-group factor (≥ 1). TOML: `split = 4`. Exact-mode splits
    /// land on fiber sub-run boundaries and are bitwise-neutral;
    /// relaxed-mode splits may land anywhere.
    pub split: usize,
    /// In-group thread pool width. TOML: `threads = "auto"` (the
    /// `FASTTUCKER_POOL_THREADS` env override, else sequential) or
    /// `threads = N` (≥ 1). Exact-mode pooling executes the sub-group
    /// coloring's waves and is bitwise-neutral; relaxed-mode pooling is
    /// the hogwild opt-in. Needs a batched kernel when > 1.
    pub threads: ThreadCount,
    /// Device-shard grid width for the parallel engine. TOML:
    /// `devices = "auto"` (the `FASTTUCKER_DEVICES` env override, else
    /// one device per worker) or `devices = N` (≥ 1, clamped loudly to
    /// `workers`). Exact-mode sharding is bitwise-neutral at every `D`;
    /// the native (serial) engine is a single device — a fixed `N > 1`
    /// there degrades loudly instead of erroring.
    pub devices: DeviceCount,
    /// Boundary-exchange mechanism for the parallel engine. TOML:
    /// `transport = "auto"` (the `FASTTUCKER_TRANSPORT` env override,
    /// else direct), `"direct"` (in-memory handover), or `"channel"`
    /// (framed, checksummed messages with retry/timeout/backoff —
    /// bitwise-identical to direct when healthy, loudly fault-tolerant
    /// otherwise). Only the parallel engine exchanges anything; fixing
    /// `"channel"` on another engine is a config error.
    pub transport: TransportKind,
    /// Boundary-exchange prefetch for the parallel engine. TOML:
    /// `prefetch = "auto"` (the `FASTTUCKER_PREFETCH` env override,
    /// else off), `"off"` (synchronous exchange at each barrier), or
    /// `"async"` (double-buffered: round r+1's panels are issued while
    /// round r computes; exact-mode applies still land at their own
    /// barriers, bitwise-identical). Fixing `"async"` needs
    /// `transport = "channel"` — the direct handover has no transfer to
    /// hide.
    pub prefetch: PrefetchMode,
    /// Relaxed-mode staleness bound (rounds) for async prefetch. TOML:
    /// `staleness = 0` (default: every panel applies at its own
    /// barrier) or `N > 0` (a panel may apply up to N rounds late —
    /// needs `exactness = "relaxed"` and `prefetch = "async"`).
    pub staleness: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            dataset: "small".into(),
            scale: 1.0,
            algo: AlgoKind::FastTucker,
            engine: EngineKind::Native,
            j: 8,
            r_core: 8,
            epochs: 20,
            workers: 1,
            seed: 42,
            test_frac: 0.1,
            hyper: SgdHyper::default(),
            artifacts_dir: "artifacts".into(),
            checkpoint: None,
            eval_every: 1,
            eval_threads: 4,
            pjrt_batch_cap: None,
            batch: BatchSizing::Auto,
            exactness: Exactness::Exact,
            lanes: Lanes::Auto,
            simd: SimdLevel::Auto,
            wide_accum: false,
            split: 1,
            threads: ThreadCount::Auto,
            devices: DeviceCount::Auto,
            transport: TransportKind::Auto,
            prefetch: PrefetchMode::Auto,
            staleness: 0,
        }
    }
}

impl TrainConfig {
    /// Load from a TOML-subset file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML-subset text. Recognized keys (all optional):
    ///
    /// ```toml
    /// dataset = "netflix-like"
    /// scale = 1.0
    /// algo = "fasttucker"
    /// engine = "native"
    /// j = 16
    /// r_core = 16
    /// epochs = 20
    /// workers = 4
    /// seed = 42
    /// test_frac = 0.1
    /// eval_every = 1
    /// eval_threads = 4
    /// artifacts_dir = "artifacts"
    /// checkpoint = "model.ftck"
    /// batch = "auto"        # or an integer group cap (0/1 = scalar kernel)
    /// exactness = "exact"   # or "relaxed" (hogwild batched plans)
    /// lanes = "auto"        # or 4 / 8 (panel-microkernel lane width)
    /// simd = "auto"         # or "scalar" / "v128" / "v256" (panel instruction set)
    /// wide_accum = false    # f64 accumulation on the relaxed path (f32 storage)
    /// split = 1             # split-group factor (>= 1)
    /// threads = "auto"      # or N >= 1 (in-group thread pool width)
    /// devices = "auto"      # or N >= 1 (device-shard grid width)
    /// transport = "auto"    # or "direct" / "channel" (framed exchange)
    /// prefetch = "auto"     # or "off" / "async" (double-buffered exchange)
    /// staleness = 0         # relaxed-mode async bound (rounds a panel may lag)
    ///
    /// [sgd]
    /// lr_factor_alpha = 0.006
    /// lr_factor_beta = 0.05
    /// lr_core_alpha = 0.0045
    /// lr_core_beta = 0.1
    /// lambda_factor = 0.01
    /// lambda_core = 0.01
    /// sample_frac = 1.0
    /// update_core = true
    /// ```
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = TrainConfig::default();
        if let Some(v) = doc.get("", "dataset") {
            cfg.dataset = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("", "scale") {
            cfg.scale = v.as_f64()?;
        }
        if let Some(v) = doc.get("", "algo") {
            cfg.algo = AlgoKind::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.get("", "engine") {
            cfg.engine = EngineKind::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.get("", "j") {
            cfg.j = v.as_usize()?;
        }
        if let Some(v) = doc.get("", "r_core") {
            cfg.r_core = v.as_usize()?;
        }
        if let Some(v) = doc.get("", "epochs") {
            cfg.epochs = v.as_usize()?;
        }
        if let Some(v) = doc.get("", "workers") {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = doc.get("", "seed") {
            cfg.seed = v.as_usize()? as u64;
        }
        if let Some(v) = doc.get("", "test_frac") {
            cfg.test_frac = v.as_f64()?;
        }
        if let Some(v) = doc.get("", "eval_every") {
            cfg.eval_every = v.as_usize()?;
        }
        if let Some(v) = doc.get("", "eval_threads") {
            cfg.eval_threads = v.as_usize()?;
        }
        if let Some(v) = doc.get("", "artifacts_dir") {
            cfg.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("", "checkpoint") {
            cfg.checkpoint = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("", "pjrt_batch_cap") {
            cfg.pjrt_batch_cap = Some(v.as_usize()?);
        }
        if let Some(v) = doc.get("", "batch") {
            cfg.batch = parse_batch(v)?;
        }
        if let Some(v) = doc.get("", "exactness") {
            cfg.exactness = parse_exactness(v.as_str()?)?;
        }
        if let Some(v) = doc.get("", "lanes") {
            cfg.lanes = parse_lanes(v)?;
        }
        if let Some(v) = doc.get("", "simd") {
            cfg.simd = parse_simd(v.as_str()?)?;
        }
        if let Some(v) = doc.get("", "wide_accum") {
            cfg.wide_accum = v.as_bool()?;
        }
        if let Some(v) = doc.get("", "split") {
            cfg.split = v.as_usize()?;
        }
        if let Some(v) = doc.get("", "threads") {
            cfg.threads = parse_threads(v)?;
        }
        if let Some(v) = doc.get("", "devices") {
            cfg.devices = parse_devices(v)?;
        }
        if let Some(v) = doc.get("", "transport") {
            cfg.transport = parse_transport(v)?;
        }
        if let Some(v) = doc.get("", "prefetch") {
            cfg.prefetch = parse_prefetch(v)?;
        }
        if let Some(v) = doc.get("", "staleness") {
            cfg.staleness = v.as_usize()?;
        }

        let mut h = SgdHyper::default();
        let g = |k: &str| doc.get("sgd", k);
        let lr_fa = g("lr_factor_alpha").map(|v| v.as_f64()).transpose()?;
        let lr_fb = g("lr_factor_beta").map(|v| v.as_f64()).transpose()?;
        if lr_fa.is_some() || lr_fb.is_some() {
            h.lr_factor = LrSchedule::new(
                lr_fa.unwrap_or(h.lr_factor.alpha as f64) as f32,
                lr_fb.unwrap_or(h.lr_factor.beta as f64) as f32,
            );
        }
        let lr_ca = g("lr_core_alpha").map(|v| v.as_f64()).transpose()?;
        let lr_cb = g("lr_core_beta").map(|v| v.as_f64()).transpose()?;
        if lr_ca.is_some() || lr_cb.is_some() {
            h.lr_core = LrSchedule::new(
                lr_ca.unwrap_or(h.lr_core.alpha as f64) as f32,
                lr_cb.unwrap_or(h.lr_core.beta as f64) as f32,
            );
        }
        if let Some(v) = g("lambda_factor") {
            h.lambda_factor = v.as_f64()? as f32;
        }
        if let Some(v) = g("lambda_core") {
            h.lambda_core = v.as_f64()? as f32;
        }
        if let Some(v) = g("sample_frac") {
            h.sample_frac = v.as_f64()?;
        }
        if let Some(v) = g("update_core") {
            h.update_core = v.as_bool()?;
        }
        cfg.hyper = h;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.exactness == Exactness::Relaxed {
            if let BatchSizing::Fixed(b) = self.batch {
                if b < 2 {
                    bail!(
                        "exactness = \"relaxed\" needs a batched kernel: set batch = \"auto\" \
                         or batch >= 2 (got {b})"
                    );
                }
            }
        }
        if self.wide_accum {
            if self.exactness != Exactness::Relaxed {
                bail!(
                    "wide_accum = true needs exactness = \"relaxed\": exact mode owes a \
                     bitwise match to the f32 scalar oracle, which f64 accumulation breaks \
                     by design"
                );
            }
            if let BatchSizing::Fixed(b) = self.batch {
                if b < 2 {
                    bail!(
                        "wide_accum = true needs a batched kernel: set batch = \"auto\" or \
                         batch >= 2 (got {b})"
                    );
                }
            }
        }
        if self.j == 0 || self.r_core == 0 {
            bail!("j and r_core must be positive");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be >= 1 (1 = evaluate after every epoch)");
        }
        if self.eval_threads == 0 {
            bail!("eval_threads must be >= 1 (1 = sequential evaluation)");
        }
        if self.split == 0 {
            bail!("split must be >= 1 (1 = split-group execution off)");
        }
        if self.split > 1 {
            if let BatchSizing::Fixed(b) = self.batch {
                if b < 2 {
                    bail!(
                        "split = {} needs a batched kernel: set batch = \"auto\" or batch >= 2",
                        self.split
                    );
                }
            }
        }
        if let ThreadCount::Fixed(t) = self.threads {
            if t == 0 {
                bail!("threads must be >= 1 or \"auto\" (1 = in-group pooling off)");
            }
            if t > 1 {
                if let BatchSizing::Fixed(b) = self.batch {
                    if b < 2 {
                        bail!(
                            "threads = {t} needs a batched kernel: set batch = \"auto\" or \
                             batch >= 2"
                        );
                    }
                }
            }
        }
        if self.devices == DeviceCount::Fixed(0) {
            bail!("devices must be >= 1 or \"auto\"");
        }
        if !(0.0..1.0).contains(&self.test_frac) {
            bail!("test_frac must be in [0, 1)");
        }
        if self.hyper.sample_frac <= 0.0 || self.hyper.sample_frac > 1.0 {
            bail!("sample_frac must be in (0, 1]");
        }
        if self.engine == EngineKind::Parallel && self.algo != AlgoKind::FastTucker {
            bail!("the parallel engine supports only fasttucker");
        }
        if self.transport == TransportKind::Channel && self.engine != EngineKind::Parallel {
            bail!(
                "transport = \"channel\" needs the parallel engine (only it exchanges \
                 device panels); set engine = \"parallel\" or transport = \"auto\""
            );
        }
        if self.prefetch == PrefetchMode::Async {
            if self.engine != EngineKind::Parallel {
                bail!(
                    "prefetch = \"async\" needs the parallel engine (only it exchanges \
                     device panels); set engine = \"parallel\" or prefetch = \"auto\""
                );
            }
            if self.transport == TransportKind::Direct {
                bail!(
                    "prefetch = \"async\" needs transport = \"channel\" (the direct \
                     in-memory handover has no transfer to hide)"
                );
            }
        }
        if self.staleness > 0 {
            if self.exactness != Exactness::Relaxed {
                bail!(
                    "staleness = {} needs exactness = \"relaxed\" (exact mode owes every \
                     panel to its own barrier)",
                    self.staleness
                );
            }
            if self.prefetch == PrefetchMode::Off {
                bail!(
                    "staleness = {} needs prefetch = \"async\" (without in-flight panels \
                     there is nothing to defer)",
                    self.staleness
                );
            }
        }
        Ok(())
    }
}

fn parse_batch(v: &TomlValue) -> Result<BatchSizing> {
    match v {
        TomlValue::Str(s) if s == "auto" => Ok(BatchSizing::Auto),
        TomlValue::Int(i) if *i >= 0 => Ok(BatchSizing::Fixed(*i as usize)),
        other => bail!(
            "batch must be \"auto\" or a non-negative integer, got {} {other:?}",
            other.type_name()
        ),
    }
}

fn parse_exactness(s: &str) -> Result<Exactness> {
    Ok(match s {
        "exact" => Exactness::Exact,
        "relaxed" | "hogwild" => Exactness::Relaxed,
        other => bail!("unknown exactness {other:?} (expected \"exact\" or \"relaxed\")"),
    })
}

fn parse_threads(v: &TomlValue) -> Result<ThreadCount> {
    let spelled = match v {
        TomlValue::Str(s) => s.clone(),
        TomlValue::Int(i) => i.to_string(),
        other => bail!(
            "threads must be \"auto\" or an integer >= 1, got {} {other:?}",
            other.type_name()
        ),
    };
    ThreadCount::parse(&spelled).ok_or_else(|| {
        anyhow!("unknown threads {spelled:?} (expected \"auto\" or an integer >= 1)")
    })
}

fn parse_devices(v: &TomlValue) -> Result<DeviceCount> {
    let spelled = match v {
        TomlValue::Str(s) => s.clone(),
        TomlValue::Int(i) => i.to_string(),
        other => bail!(
            "devices must be \"auto\" or an integer >= 1, got {} {other:?}",
            other.type_name()
        ),
    };
    DeviceCount::parse(&spelled).ok_or_else(|| {
        anyhow!("unknown devices {spelled:?} (expected \"auto\" or an integer >= 1)")
    })
}

fn parse_transport(v: &TomlValue) -> Result<TransportKind> {
    let spelled = match v {
        TomlValue::Str(s) => s.clone(),
        other => bail!(
            "transport must be \"auto\", \"direct\", or \"channel\", got {} {other:?}",
            other.type_name()
        ),
    };
    TransportKind::parse(&spelled).ok_or_else(|| {
        anyhow!("unknown transport {spelled:?} (expected \"auto\", \"direct\", or \"channel\")")
    })
}

fn parse_prefetch(v: &TomlValue) -> Result<PrefetchMode> {
    let spelled = match v {
        TomlValue::Str(s) => s.clone(),
        other => bail!(
            "prefetch must be \"auto\", \"off\", or \"async\", got {} {other:?}",
            other.type_name()
        ),
    };
    PrefetchMode::parse(&spelled).ok_or_else(|| {
        anyhow!("unknown prefetch {spelled:?} (expected \"auto\", \"off\", or \"async\")")
    })
}

fn parse_simd(s: &str) -> Result<SimdLevel> {
    SimdLevel::parse(s).ok_or_else(|| {
        anyhow!("unknown simd {s:?} (expected \"auto\", \"scalar\", \"v128\", or \"v256\")")
    })
}

fn parse_lanes(v: &TomlValue) -> Result<Lanes> {
    let spelled = match v {
        TomlValue::Str(s) => s.clone(),
        TomlValue::Int(i) => i.to_string(),
        other => bail!(
            "lanes must be \"auto\", 4, or 8, got {} {other:?}",
            other.type_name()
        ),
    };
    Lanes::parse(&spelled)
        .ok_or_else(|| anyhow!("unknown lanes {spelled:?} (expected \"auto\", 4, or 8)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_batch_and_exactness() {
        let cfg = TrainConfig::from_toml_str("batch = \"auto\"\nexactness = \"exact\"\n").unwrap();
        assert_eq!(cfg.batch, BatchSizing::Auto);
        assert_eq!(cfg.exactness, Exactness::Exact);
        let cfg = TrainConfig::from_toml_str("batch = 64\nexactness = \"relaxed\"\n").unwrap();
        assert_eq!(cfg.batch, BatchSizing::Fixed(64));
        assert_eq!(cfg.exactness, Exactness::Relaxed);
        // hogwild is an accepted alias for the paper's semantics.
        let cfg = TrainConfig::from_toml_str("exactness = \"hogwild\"\n").unwrap();
        assert_eq!(cfg.exactness, Exactness::Relaxed);

        assert!(TrainConfig::from_toml_str("batch = true").is_err());
        assert!(TrainConfig::from_toml_str("batch = \"always\"").is_err());
        assert!(TrainConfig::from_toml_str("exactness = \"sloppy\"").is_err());
        // Relaxed exactness on the scalar path is a config error.
        assert!(TrainConfig::from_toml_str("batch = 0\nexactness = \"relaxed\"").is_err());
        assert!(TrainConfig::from_toml_str("batch = 2\nexactness = \"relaxed\"").is_ok());
    }

    #[test]
    fn parses_prefetch_and_staleness() {
        let cfg = TrainConfig::from_toml_str("prefetch = \"auto\"\n").unwrap();
        assert_eq!(cfg.prefetch, PrefetchMode::Auto);
        assert_eq!(cfg.staleness, 0);
        let cfg = TrainConfig::from_toml_str(
            "engine = \"parallel\"\ntransport = \"channel\"\nprefetch = \"async\"\n",
        )
        .unwrap();
        assert_eq!(cfg.prefetch, PrefetchMode::Async);
        let cfg = TrainConfig::from_toml_str(
            "engine = \"parallel\"\ntransport = \"channel\"\nprefetch = \"async\"\n\
             exactness = \"relaxed\"\nstaleness = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.staleness, 2);

        assert!(TrainConfig::from_toml_str("prefetch = \"eager\"").is_err());
        assert!(TrainConfig::from_toml_str("prefetch = 1").is_err());
        // Async prefetch needs the parallel engine and a transfer to hide.
        assert!(TrainConfig::from_toml_str("prefetch = \"async\"").is_err());
        assert!(TrainConfig::from_toml_str(
            "engine = \"parallel\"\ntransport = \"direct\"\nprefetch = \"async\"\n"
        )
        .is_err());
        // Staleness needs relaxed exactness and in-flight panels.
        assert!(TrainConfig::from_toml_str(
            "engine = \"parallel\"\ntransport = \"channel\"\nprefetch = \"async\"\nstaleness = 1\n"
        )
        .is_err());
        assert!(TrainConfig::from_toml_str(
            "engine = \"parallel\"\ntransport = \"channel\"\nprefetch = \"off\"\n\
             exactness = \"relaxed\"\nstaleness = 1\n"
        )
        .is_err());
    }

    #[test]
    fn parses_lanes_and_split() {
        let cfg = TrainConfig::from_toml_str("lanes = \"auto\"\nsplit = 4\n").unwrap();
        assert_eq!(cfg.lanes, Lanes::Auto);
        assert_eq!(cfg.split, 4);
        let cfg = TrainConfig::from_toml_str("lanes = 8\n").unwrap();
        assert_eq!(cfg.lanes, Lanes::W8);
        let cfg = TrainConfig::from_toml_str("lanes = 4\n").unwrap();
        assert_eq!(cfg.lanes, Lanes::W4);

        assert!(TrainConfig::from_toml_str("lanes = 16").is_err());
        assert!(TrainConfig::from_toml_str("lanes = \"wide\"").is_err());
        assert!(TrainConfig::from_toml_str("split = 0").is_err());
        // Split-group execution needs a batched kernel.
        assert!(TrainConfig::from_toml_str("batch = 0\nsplit = 2").is_err());
        assert!(TrainConfig::from_toml_str("batch = \"auto\"\nsplit = 2").is_ok());
    }

    #[test]
    fn parses_simd_and_wide_accum() {
        let cfg = TrainConfig::from_toml_str("simd = \"auto\"\n").unwrap();
        assert_eq!(cfg.simd, SimdLevel::Auto);
        let cfg = TrainConfig::from_toml_str("simd = \"scalar\"\n").unwrap();
        assert_eq!(cfg.simd, SimdLevel::Scalar);
        let cfg = TrainConfig::from_toml_str("simd = \"v128\"\n").unwrap();
        assert_eq!(cfg.simd, SimdLevel::V128);
        let cfg = TrainConfig::from_toml_str("simd = \"v256\"\n").unwrap();
        assert_eq!(cfg.simd, SimdLevel::V256);
        assert!(TrainConfig::from_toml_str("simd = \"avx512\"").is_err());
        assert!(TrainConfig::from_toml_str("simd = 8").is_err());

        let cfg = TrainConfig::from_toml_str(
            "wide_accum = true\nexactness = \"relaxed\"\nbatch = \"auto\"\n",
        )
        .unwrap();
        assert!(cfg.wide_accum);
        // Wide accumulation changes the bit pattern by design: exact mode
        // (implicit or explicit) must reject it loudly, as must the
        // scalar kernel.
        assert!(TrainConfig::from_toml_str("wide_accum = true").is_err());
        assert!(TrainConfig::from_toml_str("wide_accum = true\nexactness = \"exact\"").is_err());
        assert!(TrainConfig::from_toml_str(
            "wide_accum = true\nexactness = \"relaxed\"\nbatch = 0"
        )
        .is_err());
    }

    #[test]
    fn parses_threads() {
        let cfg = TrainConfig::from_toml_str("threads = \"auto\"\n").unwrap();
        assert_eq!(cfg.threads, ThreadCount::Auto);
        let cfg = TrainConfig::from_toml_str("threads = 4\n").unwrap();
        assert_eq!(cfg.threads, ThreadCount::Fixed(4));
        let cfg = TrainConfig::from_toml_str("threads = 1\n").unwrap();
        assert_eq!(cfg.threads, ThreadCount::Fixed(1));

        assert!(TrainConfig::from_toml_str("threads = 0").is_err());
        assert!(TrainConfig::from_toml_str("threads = \"many\"").is_err());
        assert!(TrainConfig::from_toml_str("threads = true").is_err());
        // In-group pooling needs a batched kernel (like split/relaxed)…
        assert!(TrainConfig::from_toml_str("batch = 0\nthreads = 2").is_err());
        assert!(TrainConfig::from_toml_str("batch = 1\nthreads = 2").is_err());
        // …but threads = 1 and "auto" are always legal.
        assert!(TrainConfig::from_toml_str("batch = 0\nthreads = 1").is_ok());
        assert!(TrainConfig::from_toml_str("batch = 0\nthreads = \"auto\"").is_ok());
        assert!(TrainConfig::from_toml_str("batch = \"auto\"\nthreads = 2").is_ok());
    }

    #[test]
    fn parses_devices() {
        let cfg = TrainConfig::from_toml_str("devices = \"auto\"\n").unwrap();
        assert_eq!(cfg.devices, DeviceCount::Auto);
        let cfg = TrainConfig::from_toml_str("devices = 3\n").unwrap();
        assert_eq!(cfg.devices, DeviceCount::Fixed(3));
        let cfg = TrainConfig::from_toml_str("devices = 1\n").unwrap();
        assert_eq!(cfg.devices, DeviceCount::Fixed(1));

        assert!(TrainConfig::from_toml_str("devices = 0").is_err());
        assert!(TrainConfig::from_toml_str("devices = \"many\"").is_err());
        assert!(TrainConfig::from_toml_str("devices = true").is_err());
        // devices > workers is NOT a config error: the grid clamps loudly
        // at runtime (degenerate-grid satellite), so experiments with a
        // fixed device count survive a worker override.
        assert!(
            TrainConfig::from_toml_str("engine = \"parallel\"\nworkers = 2\ndevices = 4")
                .is_ok()
        );
    }

    #[test]
    fn parses_transport() {
        let cfg = TrainConfig::from_toml_str("transport = \"auto\"\n").unwrap();
        assert_eq!(cfg.transport, TransportKind::Auto);
        let cfg =
            TrainConfig::from_toml_str("engine = \"parallel\"\ntransport = \"channel\"\n")
                .unwrap();
        assert_eq!(cfg.transport, TransportKind::Channel);
        let cfg = TrainConfig::from_toml_str("transport = \"direct\"\n").unwrap();
        assert_eq!(cfg.transport, TransportKind::Direct);

        assert!(TrainConfig::from_toml_str("transport = \"carrier-pigeon\"").is_err());
        assert!(TrainConfig::from_toml_str("transport = 3").is_err());
        // Only the parallel engine exchanges panels; a fixed channel on
        // any other engine is a config error, not a silent no-op.
        assert!(TrainConfig::from_toml_str("transport = \"channel\"").is_err());
        assert!(
            TrainConfig::from_toml_str("engine = \"pjrt\"\ntransport = \"channel\"").is_err()
        );
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
# experiment config
dataset = "netflix-like"
algo = "cutucker"
engine = "native"
j = 16
r_core = 8
epochs = 5
workers = 2
seed = 7
test_frac = 0.2

[sgd]
lr_factor_alpha = 0.01
lr_factor_beta = 0.2
lambda_factor = 0.02
sample_frac = 0.5
update_core = false
"#;
        let cfg = TrainConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.dataset, "netflix-like");
        assert_eq!(cfg.algo, AlgoKind::CuTucker);
        assert_eq!(cfg.j, 16);
        assert_eq!(cfg.r_core, 8);
        assert_eq!(cfg.epochs, 5);
        assert_eq!(cfg.seed, 7);
        assert!((cfg.hyper.lr_factor.alpha - 0.01).abs() < 1e-9);
        assert!((cfg.hyper.lr_factor.beta - 0.2).abs() < 1e-9);
        assert!((cfg.hyper.lambda_factor - 0.02).abs() < 1e-9);
        assert!((cfg.hyper.sample_frac - 0.5).abs() < 1e-12);
        assert!(!cfg.hyper.update_core);
    }

    #[test]
    fn parses_eval_knobs_and_rejects_zero() {
        let cfg = TrainConfig::from_toml_str("eval_every = 3\neval_threads = 2\n").unwrap();
        assert_eq!(cfg.eval_every, 3);
        assert_eq!(cfg.eval_threads, 2);
        // Zero is a loud config error, not a silent clamp to 1.
        assert!(TrainConfig::from_toml_str("eval_every = 0").is_err());
        assert!(TrainConfig::from_toml_str("eval_threads = 0").is_err());
    }

    #[test]
    fn rejects_invalid_combinations() {
        assert!(TrainConfig::from_toml_str("j = 0").is_err());
        assert!(TrainConfig::from_toml_str("algo = \"nope\"").is_err());
        assert!(
            TrainConfig::from_toml_str("engine = \"parallel\"\nalgo = \"vest\"").is_err()
        );
    }

    #[test]
    fn algo_kind_roundtrip() {
        for k in ["fasttucker", "cutucker", "sgd_tucker", "ptucker", "vest"] {
            assert_eq!(AlgoKind::parse(k).unwrap().name(), k);
        }
    }
}
