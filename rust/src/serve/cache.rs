//! Hot-row cache for the serving path: staged query contexts
//! ([`StagedQuery`]) keyed by the user's fixed coordinates and
//! fingerprinted by the model revision — the same key-plus-fingerprint
//! discipline the planner caches use for their decisions (`worker.rs`
//! `partition_for` / `device_params_for`, `algo/fasttucker.rs`
//! `auto_cache`): a lookup can *miss* and rebuild, it can never return
//! state derived from a different model.
//!
//! Counters follow the [`crate::metrics::PlanAccum`] style: plain
//! monotone `u64`s snapshot by value, merged nowhere, asserted on by
//! tests and printed by the `serve` subcommand and `bench_serving`.

use std::collections::HashMap;

use crate::kruskal::predict::StagedQuery;

/// Monotone counters of cache behavior over the cache's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheCounters {
    /// Lookups answered from a live staged entry.
    pub hits: u64,
    /// Lookups that had to stage (absent key, or capacity 0).
    pub misses: u64,
    /// Entries dropped to make room (capacity pressure, LRU order).
    pub evictions: u64,
    /// Whole-cache drops because the model fingerprint moved (training
    /// updated the factors) — the streaming warm-start invalidation.
    pub invalidations: u64,
}

impl CacheCounters {
    /// Hit fraction of all lookups (0 when the cache was never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A cache key: the candidate mode plus the user's fixed coordinates
/// (the open slot excluded, so two queries differing only in the ignored
/// candidate coordinate share an entry).
#[derive(Clone, Debug, Hash, PartialEq, Eq)]
struct QueryKey {
    mode: usize,
    fixed: Vec<u32>,
}

impl QueryKey {
    fn new(coords: &[u32], mode: usize) -> QueryKey {
        let fixed = coords
            .iter()
            .enumerate()
            .filter_map(|(n, &c)| (n != mode).then_some(c))
            .collect();
        QueryKey { mode, fixed }
    }
}

/// LRU cache of staged query contexts, fingerprinted by model revision.
#[derive(Debug)]
pub struct HotRowCache {
    /// Max live entries; 0 disables caching (every lookup misses).
    capacity: usize,
    /// The model revision the live entries were staged from. `None`
    /// until the first insert after construction or invalidation.
    staged_for: Option<u64>,
    entries: HashMap<QueryKey, (u64, StagedQuery)>,
    /// LRU clock: bumped per lookup, stored per entry on hit/insert.
    tick: u64,
    counters: CacheCounters,
}

impl HotRowCache {
    pub fn new(capacity: usize) -> Self {
        HotRowCache {
            capacity,
            staged_for: None,
            entries: HashMap::new(),
            tick: 0,
            counters: CacheCounters::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn counters(&self) -> CacheCounters {
        self.counters
    }

    /// Look up the staged context for `(coords, mode)` under
    /// `model_revision`, staging through `stage` on a miss. A revision
    /// mismatch drops every entry first (counted once per transition) —
    /// the model moved, so nothing staged from it may be served.
    pub fn get_or_stage<F>(
        &mut self,
        coords: &[u32],
        mode: usize,
        model_revision: u64,
        stage: F,
    ) -> StagedQuery
    where
        F: FnOnce() -> StagedQuery,
    {
        if self.staged_for.is_some_and(|rev| rev != model_revision) && !self.entries.is_empty()
        {
            self.entries.clear();
            self.counters.invalidations += 1;
        }
        self.staged_for = Some(model_revision);
        self.tick += 1;
        if self.capacity == 0 {
            self.counters.misses += 1;
            return stage();
        }
        let key = QueryKey::new(coords, mode);
        if let Some((tick, staged)) = self.entries.get_mut(&key) {
            *tick = self.tick;
            self.counters.hits += 1;
            return staged.clone();
        }
        self.counters.misses += 1;
        let staged = stage();
        if self.entries.len() >= self.capacity {
            // Evict the least-recently-used entry (O(len) scan: serving
            // caches are small and the scan is branch-predictable; a heap
            // would pay its overhead on every hit instead).
            if let Some(oldest) = self
                .entries
                .iter()
                .min_by_key(|(_, (tick, _))| *tick)
                .map(|(k, _)| k.clone())
            {
                self.entries.remove(&oldest);
                self.counters.evictions += 1;
            }
        }
        self.entries.insert(key, (self.tick, staged.clone()));
        staged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kruskal::predict::stage_query;
    use crate::model::{CoreRepr, TuckerModel};
    use crate::util::Rng;

    fn model() -> TuckerModel {
        let mut rng = Rng::new(1);
        TuckerModel::init_kruskal(&mut rng, &[10, 12, 8], 4, 4)
    }

    fn staged(m: &TuckerModel, coords: &[u32]) -> StagedQuery {
        match &m.core {
            CoreRepr::Kruskal(k) => stage_query(&m.factors, k, coords, 1),
            _ => unreachable!(),
        }
    }

    #[test]
    fn hits_after_first_stage() {
        let m = model();
        let mut cache = HotRowCache::new(4);
        let coords = [3u32, 0, 5];
        for _ in 0..3 {
            cache.get_or_stage(&coords, 1, 7, || staged(&m, &coords));
        }
        let c = cache.counters();
        assert_eq!((c.hits, c.misses, c.evictions, c.invalidations), (2, 1, 0, 0));
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn candidate_slot_is_ignored_in_key() {
        let m = model();
        let mut cache = HotRowCache::new(4);
        cache.get_or_stage(&[3, 0, 5], 1, 7, || staged(&m, &[3, 0, 5]));
        // Same fixed coords, different (ignored) candidate slot: a hit.
        cache.get_or_stage(&[3, 11, 5], 1, 7, || staged(&m, &[3, 11, 5]));
        assert_eq!(cache.counters().hits, 1);
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let m = model();
        let mut cache = HotRowCache::new(2);
        let users = [[0u32, 0, 0], [1, 0, 0], [2, 0, 0]];
        for u in &users {
            cache.get_or_stage(u, 1, 7, || staged(&m, u));
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.counters().evictions, 1);
        // User 0 was evicted (LRU); user 2 is live.
        cache.get_or_stage(&users[2], 1, 7, || staged(&m, &users[2]));
        assert_eq!(cache.counters().hits, 1);
        cache.get_or_stage(&users[0], 1, 7, || staged(&m, &users[0]));
        assert_eq!(cache.counters().misses, 4);
    }

    #[test]
    fn revision_change_invalidates_everything() {
        let m = model();
        let mut cache = HotRowCache::new(4);
        let coords = [3u32, 0, 5];
        cache.get_or_stage(&coords, 1, 7, || staged(&m, &coords));
        cache.get_or_stage(&coords, 1, 8, || staged(&m, &coords));
        let c = cache.counters();
        assert_eq!(c.invalidations, 1);
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 2);
        // Back on the new revision: a hit again.
        cache.get_or_stage(&coords, 1, 8, || staged(&m, &coords));
        assert_eq!(cache.counters().hits, 1);
    }

    #[test]
    fn zero_capacity_never_caches() {
        let m = model();
        let mut cache = HotRowCache::new(0);
        let coords = [3u32, 0, 5];
        for _ in 0..3 {
            cache.get_or_stage(&coords, 1, 7, || staged(&m, &coords));
        }
        let c = cache.counters();
        assert_eq!((c.hits, c.misses), (0, 3));
        assert!(cache.is_empty());
    }
}
