//! Serving layer: long-lived scoring over a live model (ISSUE 9).
//!
//! Training produces a [`TuckerModel`](crate::model::TuckerModel);
//! serving answers *queries* against it — "for this user's fixed
//! coordinates, rank these candidate items" — at a throughput the
//! pointwise [`predict`](crate::model::TuckerModel::predict) loop
//! cannot reach, without ever changing a single answer:
//!
//! * **[`score`]** — [`Scorer`] stages each query's fixed coordinates
//!   once through [`crate::kruskal::predict::stage_query`] and scores
//!   the whole candidate panel with the lane-blocked
//!   [`score_panel`](crate::kruskal::predict::score_panel). The batch
//!   path is **bitwise-identical** to the pointwise oracle (the same
//!   f32 association, property-pinned over random layouts), so serving
//!   is an optimization, never an approximation. Top-k is deterministic:
//!   score descending, item id ascending on ties.
//! * **[`cache`]** — [`HotRowCache`] keeps recent staged contexts keyed
//!   by `(mode, fixed coords)` and fingerprinted by a **model revision**,
//!   the same key-plus-fingerprint discipline as the planner decision
//!   caches: a fingerprint move (any warm-start training in the owning
//!   [`Session`](crate::coordinator::session::Session)) drops every
//!   entry before the next lookup, so a staged row can never outlive
//!   the factors it was cut from. Hit/miss/eviction/invalidation
//!   counters are plain monotone `u64`s in the
//!   [`PlanAccum`](crate::metrics::PlanAccum) style.
//!
//! The serving loop composes with streaming ingest through
//! [`coordinator::session`](crate::coordinator::session): appends land
//! between epochs at the session boundary, warm-start epochs resume
//! from the live factors, and the session bumps the model revision so
//! exactly the touched caches (hot rows here, partition/planner
//! fingerprints in the engines) rebuild. Exact-mode training stays
//! bitwise because nothing mutates mid-epoch.
//!
//! Throughput is measured by `benches/bench_serving.rs`
//! (predictions/sec, cache hit rate) and gated against
//! `BENCH_baseline.json` floors in CI alongside the kernel benches.

pub mod cache;
pub mod score;

pub use cache::{CacheCounters, HotRowCache};
pub use score::{Query, ScoredItem, Scorer};
