//! Batch scoring and per-user top-k over candidate panels.
//!
//! The scorer is a thin serving loop over the oracle-pinned prediction
//! layer ([`crate::kruskal::predict`]): each query's fixed coordinates
//! are staged once (through the [`HotRowCache`], so repeat users skip
//! the staging pass entirely), the candidate panel is scored by the
//! lane-blocked [`score_panel`] — **bitwise-identical to the pointwise
//! [`TuckerModel::predict`] oracle**, property-pinned in
//! `kruskal::predict` and re-pinned end-to-end here — and top-k
//! selection orders by `(score desc, candidate asc)` — NaN scores sort
//! strictly last — so ties are deterministic across runs and layouts.
//!
//! Dense-cored baseline models are served too (the dispatch is the same
//! [`predict`](crate::kruskal::predict::predict) everywhere), but only
//! the Kruskal path has a staged fast path; dense scoring is the
//! pointwise oracle per candidate, trivially bitwise.

use crate::kruskal::predict::{predict, score_panel, stage_query};
use crate::model::{CoreRepr, TuckerModel};
use crate::serve::cache::{CacheCounters, HotRowCache};

/// One serving request: fixed coordinates with one mode left open, and
/// the candidate panel to score into that slot. `coords[candidate_mode]`
/// is ignored.
#[derive(Clone, Debug)]
pub struct Query {
    pub coords: Vec<u32>,
    /// The open mode (items live here; mode 1 in the recommender
    /// framing, user = mode 0).
    pub candidate_mode: usize,
    pub candidates: Vec<u32>,
}

/// One ranked result.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScoredItem {
    pub item: u32,
    pub score: f32,
}

/// The serving scorer: the hot-row cache plus scratch buffers.
#[derive(Debug)]
pub struct Scorer {
    cache: HotRowCache,
    scores: Vec<f32>,
}

impl Scorer {
    /// `cache_capacity` bounds the hot-row cache (0 = uncached).
    pub fn new(cache_capacity: usize) -> Self {
        Scorer { cache: HotRowCache::new(cache_capacity), scores: Vec::new() }
    }

    pub fn cache_counters(&self) -> CacheCounters {
        self.cache.counters()
    }

    /// Score `query`'s candidate panel under `model` at `model_revision`
    /// (the session's monotone factor-state counter — any training
    /// between calls must bump it so staged rows cannot outlive the
    /// factors they were cut from). Returns one score per candidate,
    /// bitwise-equal to `model.predict` with the candidate substituted.
    pub fn score(
        &mut self,
        model: &TuckerModel,
        model_revision: u64,
        query: &Query,
    ) -> Vec<f32> {
        let order = model.order();
        assert!(
            query.candidate_mode < order,
            "candidate mode {} out of range for order {order}",
            query.candidate_mode
        );
        assert_eq!(query.coords.len(), order, "query coords must cover every mode");
        match &model.core {
            CoreRepr::Kruskal(core) => {
                let staged = self.cache.get_or_stage(
                    &query.coords,
                    query.candidate_mode,
                    model_revision,
                    || stage_query(&model.factors, core, &query.coords, query.candidate_mode),
                );
                score_panel(&staged, &model.factors, core, &query.candidates, &mut self.scores);
                self.scores.clone()
            }
            CoreRepr::Dense(_) => {
                let mut full = query.coords.clone();
                query
                    .candidates
                    .iter()
                    .map(|&c| {
                        full[query.candidate_mode] = c;
                        predict(&model.factors, &model.core, &full)
                    })
                    .collect()
            }
        }
    }

    /// Top-k over the query's candidates: `(score desc, item asc)` with
    /// NaN scores sorted strictly last, truncated to `k`. Duplicate
    /// candidates rank independently.
    pub fn top_k(
        &mut self,
        model: &TuckerModel,
        model_revision: u64,
        query: &Query,
        k: usize,
    ) -> Vec<ScoredItem> {
        let scores = self.score(model, model_revision, query);
        let mut ranked: Vec<ScoredItem> = query
            .candidates
            .iter()
            .zip(scores)
            .map(|(&item, score)| ScoredItem { item, score })
            .collect();
        // NaN-scored candidates (possible when a model is served mid-blowup,
        // e.g. a diverged relaxed run) must sort strictly LAST, never
        // displacing finite scores. The old `partial_cmp(..).unwrap_or(Equal)`
        // treated NaN as tied-with-everything, so `sort_by` (which is not a
        // total order under that comparator) could leave a NaN anywhere in
        // the ranking — including above real recommendations. Note
        // `total_cmp` alone is not the fix either: it orders +NaN *above*
        // +inf, so a descending `total_cmp` would put NaN FIRST.
        ranked.sort_by(|a, b| match (a.score.is_nan(), b.score.is_nan()) {
            (true, true) => a.item.cmp(&b.item),
            (true, false) => std::cmp::Ordering::Greater,
            (false, true) => std::cmp::Ordering::Less,
            (false, false) => b.score.total_cmp(&a.score).then(a.item.cmp(&b.item)),
        });
        ranked.truncate(k);
        ranked
    }

    /// Score a batch of queries, returning each query's top-k.
    pub fn top_k_batch(
        &mut self,
        model: &TuckerModel,
        model_revision: u64,
        queries: &[Query],
        k: usize,
    ) -> Vec<Vec<ScoredItem>> {
        queries
            .iter()
            .map(|q| self.top_k(model, model_revision, q, k))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::util::Rng;

    fn kruskal_model(rng: &mut Rng, dims: &[usize], j: usize, r: usize) -> TuckerModel {
        TuckerModel::init_kruskal(rng, dims, j, r)
    }

    #[test]
    fn prop_batch_scores_bitwise_equal_pointwise_oracle() {
        // The serving-layer acceptance pin, end to end through the cache:
        // batch scores == `model.predict` bit for bit, over random
        // layouts, candidate modes, candidate counts, and cache states
        // (repeat queries exercise the hit path).
        forall("serve batch scoring bitwise vs predict", 25, |rng| {
            let order = 2 + rng.gen_range(3);
            let dims: Vec<usize> = (0..order).map(|_| 4 + rng.gen_range(16)).collect();
            let j = 1 + rng.gen_range(10);
            let r = 1 + rng.gen_range(10);
            let mut r2 = Rng::new(rng.next_u64());
            let model = kruskal_model(&mut r2, &dims, j, r);
            let mode = rng.gen_range(order);
            let mut scorer = Scorer::new(1 + rng.gen_range(3));
            for _ in 0..3 {
                let coords: Vec<u32> =
                    dims.iter().map(|&d| rng.gen_range(d) as u32).collect();
                let candidates: Vec<u32> = (0..1 + rng.gen_range(30))
                    .map(|_| rng.gen_range(dims[mode]) as u32)
                    .collect();
                let q = Query { coords: coords.clone(), candidate_mode: mode, candidates };
                let scores = scorer.score(&model, 1, &q);
                let mut full = coords;
                for (s, &c) in q.candidates.iter().enumerate() {
                    full[mode] = c;
                    assert_eq!(
                        scores[s].to_bits(),
                        model.predict(&full).to_bits(),
                        "mode {mode} candidate {c}"
                    );
                }
            }
        });
    }

    #[test]
    fn top_k_orders_desc_with_deterministic_ties() {
        let mut rng = Rng::new(5);
        let model = kruskal_model(&mut rng, &[6, 20, 5], 4, 4);
        let q = Query {
            coords: vec![2, 0, 3],
            candidate_mode: 1,
            // Duplicate candidate 7: identical scores, item-id tiebreak.
            candidates: (0..20).chain([7u32]).collect(),
        };
        let mut scorer = Scorer::new(8);
        let top = scorer.top_k(&model, 1, &q, 5);
        assert_eq!(top.len(), 5);
        for w in top.windows(2) {
            assert!(
                w[0].score > w[1].score
                    || (w[0].score == w[1].score && w[0].item <= w[1].item)
            );
        }
        // k larger than the panel: everything comes back, still sorted.
        let all = scorer.top_k(&model, 1, &q, 100);
        assert_eq!(all.len(), 21);
    }

    #[test]
    fn repeat_users_hit_the_cache() {
        let mut rng = Rng::new(6);
        let model = kruskal_model(&mut rng, &[10, 30, 4], 4, 4);
        let mut scorer = Scorer::new(16);
        let q = Query {
            coords: vec![3, 0, 1],
            candidate_mode: 1,
            candidates: (0..30).collect(),
        };
        scorer.top_k(&model, 1, &q, 10);
        scorer.top_k(&model, 1, &q, 10);
        let c = scorer.cache_counters();
        assert_eq!((c.hits, c.misses), (1, 1));
        // Training bumps the revision: staged rows must be re-cut.
        scorer.top_k(&model, 2, &q, 10);
        let c = scorer.cache_counters();
        assert_eq!(c.invalidations, 1);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn top_k_sorts_nan_scores_last_never_displacing_finite() {
        // Regression (ISSUE 10 satellite): the old comparator used
        // `partial_cmp(..).unwrap_or(Equal)`, which treats NaN as equal to
        // everything — an intransitive comparator under which `sort_by`
        // could leave a NaN-scored candidate anywhere, including ranked
        // above real items. NaN must sort strictly last.
        let mut rng = Rng::new(11);
        let mut model = kruskal_model(&mut rng, &[6, 20, 5], 4, 4);
        // Poison two item rows so their scores come out NaN.
        for item in [3usize, 12] {
            model.factors.mat_mut(1).row_mut(item).fill(f32::NAN);
        }
        let q = Query {
            coords: vec![2, 0, 3],
            candidate_mode: 1,
            candidates: (0..20).collect(),
        };
        let mut scorer = Scorer::new(8);
        let all = scorer.top_k(&model, 1, &q, 20);
        assert_eq!(all.len(), 20);
        // The two NaN candidates land in the last two slots, item-ordered.
        assert!(all[18].score.is_nan() && all[19].score.is_nan());
        assert_eq!((all[18].item, all[19].item), (3, 12));
        // Every finite score ranks above every NaN, and finite prefix is
        // descending with item-asc tiebreak.
        for w in all[..18].windows(2) {
            assert!(!w[0].score.is_nan() && !w[1].score.is_nan());
            assert!(
                w[0].score > w[1].score
                    || (w[0].score == w[1].score && w[0].item <= w[1].item)
            );
        }
        // A k that only covers the finite candidates must not contain NaN:
        // NaN never displaces a finite score.
        let top = scorer.top_k(&model, 1, &q, 18);
        assert!(top.iter().all(|s| !s.score.is_nan()));
    }

    #[test]
    fn top_k_batch_sorts_nan_scores_last() {
        // Same regression pinned through the batch entry point.
        let mut rng = Rng::new(12);
        let mut model = kruskal_model(&mut rng, &[6, 10, 5], 4, 4);
        model.factors.mat_mut(1).row_mut(0).fill(f32::NAN);
        let queries: Vec<Query> = (0..3)
            .map(|u| Query {
                coords: vec![u, 0, 1],
                candidate_mode: 1,
                candidates: (0..10).collect(),
            })
            .collect();
        let mut scorer = Scorer::new(8);
        for ranked in scorer.top_k_batch(&model, 1, &queries, 10) {
            assert_eq!(ranked.len(), 10);
            assert!(ranked[9].score.is_nan() && ranked[9].item == 0);
            assert!(ranked[..9].iter().all(|s| !s.score.is_nan()));
        }
    }

    #[test]
    fn dense_core_serves_through_the_same_api() {
        let mut rng = Rng::new(7);
        let model = TuckerModel::init_dense(&mut rng, &[8, 12, 6], 4);
        let mut scorer = Scorer::new(4);
        let q = Query {
            coords: vec![1, 0, 2],
            candidate_mode: 1,
            candidates: (0..12).collect(),
        };
        let scores = scorer.score(&model, 1, &q);
        let mut full = q.coords.clone();
        for (s, &c) in q.candidates.iter().enumerate() {
            full[1] = c;
            assert_eq!(scores[s].to_bits(), model.predict(&full).to_bits());
        }
    }
}
