//! Metrics: timers, counters, and simple streaming statistics used by the
//! trainer, the multi-device scheduler (communication volume), and the
//! bench harnesses.

use std::time::{Duration, Instant};

/// A resumable stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    started: Option<Instant>,
    accumulated: Duration,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { started: None, accumulated: Duration::ZERO }
    }

    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        if let Some(t) = self.started.take() {
            self.accumulated += t.elapsed();
        }
    }

    pub fn elapsed(&self) -> Duration {
        let running = self.started.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
        self.accumulated + running
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.started = None;
        self.accumulated = Duration::ZERO;
    }
}

/// Streaming mean/variance/min/max (Welford).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Observability snapshot of one [`BatchPlan`](crate::kernel::BatchPlan):
/// how effectively the planner packed samples into groups (the batching
/// diagnostics ISSUE 2 / the ROADMAP's cost-model follow-up ask for).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PlanStats {
    /// Nonzeros the plan covers.
    pub samples: usize,
    /// Groups (batched kernel invocations' outer loop).
    pub n_groups: usize,
    /// Fiber sub-runs summed over groups (tile-occupancy numerator).
    pub fiber_slots: usize,
    /// Group-size cap the plan was built with.
    pub cap: usize,
    /// Fiber-tile width the plan was built with.
    pub tile: usize,
    /// Configured panel-microkernel lane width (0 = auto; see
    /// [`Lanes::code`](crate::kernel::panel::Lanes::code)).
    pub lanes: usize,
    /// Split-group factor the plan was built with (1 = off).
    pub split: usize,
    /// Group boundaries the split-group rule introduced.
    pub splits: usize,
    /// In-group pool threads that executed the plan (1 = sequential
    /// dispatch; set by the execution layer, not the plan builder).
    pub threads: usize,
    /// Barrier-separated waves the plan actually executed as (exact
    /// pooled dispatch: the coloring's wave count; relaxed pooled
    /// dispatch: 1). Stays 0 on any sequential execution — including an
    /// exact pass whose coloring the conflict-density gate rejected.
    pub waves: usize,
    /// Device that executed the pass under a device grid
    /// ([`DeviceGrid`](crate::parallel::DeviceGrid); 0 on single-device
    /// and serial paths).
    pub device: usize,
    /// Planner degrade marker: requested relaxed/split semantics could
    /// not engage on a degenerate workload (see
    /// [`choose_params`](crate::kernel::planner::choose_params)), or the
    /// pass ran on a degenerate device grid (clamped device count, empty
    /// shard, grid wider than the shortest mode).
    pub degraded: bool,
}

impl PlanStats {
    /// Mean samples per group — the quantity fiber tiling exists to lift
    /// on hollow tensors.
    pub fn mean_group_len(&self) -> f64 {
        if self.n_groups == 0 {
            0.0
        } else {
            self.samples as f64 / self.n_groups as f64
        }
    }

    /// Mean fiber sub-runs per group (≤ tile).
    pub fn mean_fibers_per_group(&self) -> f64 {
        if self.n_groups == 0 {
            0.0
        } else {
            self.fiber_slots as f64 / self.n_groups as f64
        }
    }

    /// Fraction of the panel capacity the mean group fills.
    pub fn occupancy(&self) -> f64 {
        if self.n_groups == 0 || self.cap == 0 {
            0.0
        } else {
            self.samples as f64 / (self.n_groups * self.cap) as f64
        }
    }

    /// Mean sub-groups per coloring wave — the parallel width the
    /// in-group pool exploited (0 when the plan was never colored).
    pub fn wave_occupancy(&self) -> f64 {
        if self.waves == 0 {
            0.0
        } else {
            self.n_groups as f64 / self.waves as f64
        }
    }
}

/// Accumulator over many [`PlanStats`] (e.g. every worker-pass plan of a
/// multi-device epoch): totals plus the caps in effect.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanAccum {
    pub builds: u64,
    pub samples: u64,
    pub groups: u64,
    pub fiber_slots: u64,
    /// Largest cap / tile observed (uniform in practice: one planner
    /// decision per dataset).
    pub cap: usize,
    pub tile: usize,
    /// Largest configured lane width (0 = auto) / split factor observed.
    pub lanes: usize,
    pub split: usize,
    /// Split-rule group boundaries summed over plans.
    pub splits: u64,
    /// Largest in-group pool width observed executing a plan.
    pub threads: usize,
    /// Coloring waves summed over pooled plans (with `groups`, gives the
    /// mean wave occupancy of the epoch).
    pub waves: u64,
    /// Plans whose relaxed/split request was planner-degraded, or that
    /// ran on a degenerate device grid.
    pub degraded: u64,
    /// Widest device grid observed executing plans (0 = nothing ran):
    /// the max of the configured grid widths recorded per epoch and the
    /// per-pass device attributions ([`PlanStats::device`] + 1).
    pub devices: usize,
    /// Busiest device's samples, summed per epoch (see
    /// [`Self::device_occupancy`]); recorded by
    /// [`Self::record_device_epoch`].
    pub device_samples_max: u64,
    /// Mean samples per device, summed per epoch (`epoch samples /
    /// epoch grid width`) — the occupancy numerator, kept separately
    /// from `samples` so [`Self::device_occupancy`] stays coherent when
    /// accumulators from different grid widths merge.
    pub device_samples_mean: f64,
    /// Factor rows shipped **across devices** by the boundary-row
    /// exchange (intra-device chunk handovers are free — this is the new
    /// inter-device counter, distinct from the per-worker
    /// [`CommLedger`]).
    pub comm_rows: u64,
    /// Bytes of inter-device traffic: boundary factor rows plus the
    /// per-epoch Eq. 17 core-gradient panels shipped to the root device.
    pub comm_bytes: u64,
    /// Transport frames handed to the channel exchange (first sends +
    /// resends; 0 under the direct transport). Recorded per epoch by
    /// [`Self::record_transport`] (ISSUE 7).
    pub frames_sent: u64,
    /// Serialized bytes of those frames (headers + payloads + checksums).
    pub frame_bytes: u64,
    /// Frames that arrived, validated, and filled an expected panel.
    pub frames_delivered: u64,
    /// Frames resent after a timeout/backoff window found panels missing
    /// (the drop-recovery counter — the acceptance criterion's "retry
    /// counters > 0" lives here).
    pub transport_retries: u64,
    /// Frames discarded by sequence-number dedup (duplicate recovery).
    pub transport_dups: u64,
    /// Frames discarded for checksum/framing damage (corruption caught
    /// before it could touch the factors).
    pub transport_checksum_failures: u64,
    /// Out-of-order arrivals observed (recovered by panel-slot matching).
    pub transport_reorders: u64,
    /// Drain attempts that found panels still missing (delay/drop cost).
    pub transport_timeouts: u64,
    /// Panels issued into the transport *before* their round barrier by
    /// the async prefetch path (ISSUE 8; 0 when prefetch is off).
    pub prefetch_issued: u64,
    /// Exchange cost overlapped with compute (ISSUE 8): seconds spent
    /// serializing + issuing prefetched panels and polling the transport
    /// while compute was still in flight — cost the round barrier never
    /// sees.
    pub comm_hidden_secs: f64,
    /// Exchange cost the round barriers *did* see: seconds the
    /// coordinator spent blocking in collect/exchange calls.
    pub comm_exposed_secs: f64,
}

impl PlanAccum {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, s: &PlanStats) {
        self.builds += 1;
        self.samples += s.samples as u64;
        self.groups += s.n_groups as u64;
        self.fiber_slots += s.fiber_slots as u64;
        self.cap = self.cap.max(s.cap);
        self.tile = self.tile.max(s.tile);
        self.lanes = self.lanes.max(s.lanes);
        self.split = self.split.max(s.split);
        self.splits += s.splits as u64;
        self.threads = self.threads.max(s.threads);
        self.waves += s.waves as u64;
        self.degraded += s.degraded as u64;
        // Widest executing device id seen on a pass (the engine's
        // per-epoch `record_device_epoch` carries the configured width;
        // this keeps the per-pass attribution observable too).
        self.devices = self.devices.max(s.device + 1);
    }

    pub fn merge(&mut self, other: &PlanAccum) {
        self.builds += other.builds;
        self.samples += other.samples;
        self.groups += other.groups;
        self.fiber_slots += other.fiber_slots;
        self.cap = self.cap.max(other.cap);
        self.tile = self.tile.max(other.tile);
        self.lanes = self.lanes.max(other.lanes);
        self.split = self.split.max(other.split);
        self.splits += other.splits;
        self.threads = self.threads.max(other.threads);
        self.waves += other.waves;
        self.degraded += other.degraded;
        self.devices = self.devices.max(other.devices);
        self.device_samples_max += other.device_samples_max;
        self.device_samples_mean += other.device_samples_mean;
        self.comm_rows += other.comm_rows;
        self.comm_bytes += other.comm_bytes;
        self.frames_sent += other.frames_sent;
        self.frame_bytes += other.frame_bytes;
        self.frames_delivered += other.frames_delivered;
        self.transport_retries += other.transport_retries;
        self.transport_dups += other.transport_dups;
        self.transport_checksum_failures += other.transport_checksum_failures;
        self.transport_reorders += other.transport_reorders;
        self.transport_timeouts += other.transport_timeouts;
        self.prefetch_issued += other.prefetch_issued;
        self.comm_hidden_secs += other.comm_hidden_secs;
        self.comm_exposed_secs += other.comm_exposed_secs;
    }

    /// Record one device-grid epoch: the grid width, the epoch's total
    /// samples, and the busiest device's sample count (the per-device
    /// occupancy numerator/denominator pair).
    pub fn record_device_epoch(
        &mut self,
        devices: usize,
        epoch_samples: u64,
        max_device_samples: u64,
    ) {
        self.devices = self.devices.max(devices);
        self.device_samples_mean += epoch_samples as f64 / devices.max(1) as f64;
        self.device_samples_max += max_device_samples;
    }

    /// Record inter-device communication (boundary factor rows and/or
    /// core-gradient panel bytes).
    pub fn record_comm(&mut self, rows: u64, bytes: u64) {
        self.comm_rows += rows;
        self.comm_bytes += bytes;
    }

    /// Record one epoch's channel-transport counters (ISSUE 7): traffic
    /// volumes plus every recovered-fault event. Recovery is *loud* —
    /// these counters and a per-epoch warning — but deliberately not
    /// [`Self::degraded`], which stays reserved for geometry/config
    /// trouble: a transparently recovered exchange is still a correct
    /// exchange.
    pub fn record_transport(&mut self, ts: &crate::parallel::TransportStats) {
        self.frames_sent += ts.frames_sent;
        self.frame_bytes += ts.bytes_sent;
        self.frames_delivered += ts.frames_delivered;
        self.transport_retries += ts.retries;
        self.transport_dups += ts.duplicates_dropped;
        self.transport_checksum_failures += ts.checksum_failures;
        self.transport_reorders += ts.reorders;
        self.transport_timeouts += ts.timeouts;
    }

    /// Record one epoch's prefetch-overlap measurements (ISSUE 8): how
    /// many panels were issued ahead of their barrier, and how the
    /// exchange cost split into hidden (overlapped with compute) vs
    /// exposed (blocking at a barrier) seconds.
    pub fn record_overlap(&mut self, issued: u64, hidden_secs: f64, exposed_secs: f64) {
        self.prefetch_issued += issued;
        self.comm_hidden_secs += hidden_secs;
        self.comm_exposed_secs += exposed_secs;
    }

    /// Fraction of the measured exchange cost hidden behind compute, in
    /// [0, 1] — `None` until any exchange time was measured. 1.0 means
    /// every barrier found its panels already delivered (the paper's
    /// fully-overlapped communication ideal); 0.0 means every byte was
    /// paid for while blocking at a barrier (the synchronous path).
    pub fn overlap_efficiency(&self) -> Option<f64> {
        let total = self.comm_hidden_secs + self.comm_exposed_secs;
        if total > 0.0 {
            Some(self.comm_hidden_secs / total)
        } else {
            None
        }
    }

    /// Total detected transport fault events (anything a healthy
    /// exchange would not produce) — 0 for a clean run, > 0 whenever
    /// injection (or a real fault) was survived.
    pub fn transport_faults(&self) -> u64 {
        self.transport_retries
            + self.transport_dups
            + self.transport_checksum_failures
            + self.transport_reorders
            + self.transport_timeouts
    }

    pub fn mean_group_len(&self) -> f64 {
        if self.groups == 0 {
            0.0
        } else {
            self.samples as f64 / self.groups as f64
        }
    }

    pub fn mean_fibers_per_group(&self) -> f64 {
        if self.groups == 0 {
            0.0
        } else {
            self.fiber_slots as f64 / self.groups as f64
        }
    }

    pub fn occupancy(&self) -> f64 {
        if self.groups == 0 || self.cap == 0 {
            0.0
        } else {
            self.samples as f64 / (self.groups as usize * self.cap) as f64
        }
    }

    /// Per-device load balance: mean samples per device over the busiest
    /// device's samples (both summed per epoch), in (0, 1] — 1.0 means a
    /// perfectly balanced shard assignment (the paper's
    /// near-linear-scaling precondition), 0.0 means no device grid ran.
    /// Coherent under [`Self::merge`] even across different grid widths
    /// (each epoch contributes its own mean/width ratio).
    pub fn device_occupancy(&self) -> f64 {
        if self.device_samples_max == 0 {
            0.0
        } else {
            self.device_samples_mean / self.device_samples_max as f64
        }
    }
}

/// Communication-volume ledger for the multi-device simulation: counts the
/// bytes the paper's parameter-exchange step would move over NVLink/PCIe.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    /// Bytes of factor-chunk exchanges between workers at round boundaries.
    pub factor_bytes: u64,
    /// Bytes of core-gradient all-reduce traffic.
    pub core_bytes: u64,
    /// Number of exchange events.
    pub events: u64,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_factor_exchange(&mut self, bytes: u64) {
        self.factor_bytes += bytes;
        self.events += 1;
    }

    pub fn record_core_allreduce(&mut self, bytes: u64) {
        self.core_bytes += bytes;
        self.events += 1;
    }

    pub fn total_bytes(&self) -> u64 {
        self.factor_bytes + self.core_bytes
    }

    pub fn merge(&mut self, other: &CommLedger) {
        self.factor_bytes += other.factor_bytes;
        self.core_bytes += other.core_bytes;
        self.events += other.events;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let first = sw.elapsed();
        assert!(first >= Duration::from_millis(4));
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.elapsed() > first);
        sw.reset();
        assert_eq!(sw.elapsed(), Duration::ZERO);
    }

    #[test]
    fn stats_moments() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn plan_stats_ratios() {
        let s = PlanStats {
            samples: 120,
            n_groups: 10,
            fiber_slots: 40,
            cap: 24,
            tile: 8,
            lanes: 8,
            split: 2,
            splits: 3,
            threads: 2,
            waves: 5,
            device: 1,
            degraded: true,
        };
        assert!((s.mean_group_len() - 12.0).abs() < 1e-12);
        assert!((s.mean_fibers_per_group() - 4.0).abs() < 1e-12);
        assert!((s.occupancy() - 0.5).abs() < 1e-12);
        assert!((s.wave_occupancy() - 2.0).abs() < 1e-12);
        let empty = PlanStats::default();
        assert_eq!(empty.mean_group_len(), 0.0);
        assert_eq!(empty.occupancy(), 0.0);
        assert_eq!(empty.wave_occupancy(), 0.0);

        let mut acc = PlanAccum::new();
        acc.record(&s);
        acc.record(&s);
        assert_eq!(acc.builds, 2);
        assert!((acc.mean_group_len() - 12.0).abs() < 1e-12);
        assert!((acc.mean_fibers_per_group() - 4.0).abs() < 1e-12);
        assert!((acc.occupancy() - 0.5).abs() < 1e-12);
        assert_eq!(acc.lanes, 8);
        assert_eq!(acc.split, 2);
        assert_eq!(acc.splits, 6);
        assert_eq!(acc.threads, 2);
        assert_eq!(acc.waves, 10);
        assert_eq!(acc.degraded, 2);
        let mut acc2 = PlanAccum::new();
        acc2.merge(&acc);
        assert_eq!(acc2.samples, 240);
        assert_eq!(acc2.splits, 6);
        assert_eq!(acc2.waves, 10);
        assert_eq!(acc2.threads, 2);
        assert_eq!(acc2.degraded, 2);
    }

    #[test]
    fn device_epoch_and_comm_accounting() {
        let mut acc = PlanAccum::new();
        assert_eq!(acc.device_occupancy(), 0.0);
        // Two epochs on a 2-device grid: 120 samples each, busiest
        // device 80 then 60 -> occupancy = (60 + 60)/(80 + 60).
        acc.record_device_epoch(2, 120, 80);
        acc.record_device_epoch(2, 120, 60);
        acc.record_comm(50, 800);
        acc.record_comm(0, 256);
        assert_eq!(acc.devices, 2);
        assert_eq!(acc.device_samples_max, 140);
        assert_eq!(acc.comm_rows, 50);
        assert_eq!(acc.comm_bytes, 1056);
        assert!((acc.device_occupancy() - 120.0 / 140.0).abs() < 1e-12);
        // Perfect balance reaches 1.0.
        let mut even = PlanAccum::new();
        even.record_device_epoch(4, 100, 25);
        assert!((even.device_occupancy() - 1.0).abs() < 1e-12);
        // merge() carries the counters, and the merged occupancy stays
        // coherent across different grid widths (each epoch contributes
        // its own mean/width ratio): (120 + 25)/(140 + 25).
        let mut merged = PlanAccum::new();
        merged.merge(&acc);
        merged.merge(&even);
        assert_eq!(merged.devices, 4);
        assert_eq!(merged.device_samples_max, 165);
        assert_eq!(merged.comm_rows, 50);
        assert_eq!(merged.comm_bytes, 1056);
        assert!((merged.device_occupancy() - 145.0 / 165.0).abs() < 1e-12);
        let (lo, hi) = (
            acc.device_occupancy().min(even.device_occupancy()),
            acc.device_occupancy().max(even.device_occupancy()),
        );
        assert!(merged.device_occupancy() >= lo && merged.device_occupancy() <= hi);
    }

    #[test]
    fn transport_counter_block_records_and_merges() {
        // ISSUE 7: the transport block must flow through record_transport
        // AND field-by-field merge (the known PlanAccum foot-gun: a new
        // counter that misses merge() silently vanishes when per-round
        // accumulators fold into the engine's).
        let ts = crate::parallel::TransportStats {
            frames_sent: 10,
            bytes_sent: 4000,
            frames_delivered: 9,
            retries: 2,
            duplicates_dropped: 1,
            checksum_failures: 3,
            reorders: 1,
            timeouts: 2,
        };
        let mut acc = PlanAccum::new();
        assert_eq!(acc.transport_faults(), 0);
        acc.record_transport(&ts);
        assert_eq!(acc.frames_sent, 10);
        assert_eq!(acc.frame_bytes, 4000);
        assert_eq!(acc.frames_delivered, 9);
        assert_eq!(acc.transport_retries, 2);
        assert_eq!(acc.transport_dups, 1);
        assert_eq!(acc.transport_checksum_failures, 3);
        assert_eq!(acc.transport_reorders, 1);
        assert_eq!(acc.transport_timeouts, 2);
        assert_eq!(acc.transport_faults(), 9);
        let mut merged = PlanAccum::new();
        merged.merge(&acc);
        merged.merge(&acc);
        assert_eq!(merged.frames_sent, 20);
        assert_eq!(merged.frame_bytes, 8000);
        assert_eq!(merged.frames_delivered, 18);
        assert_eq!(merged.transport_faults(), 18);
    }

    #[test]
    fn overlap_block_records_and_merges() {
        // ISSUE 8: the prefetch-overlap block through record_overlap AND
        // merge (same foot-gun as the transport block above), plus the
        // efficiency ratio's edge cases.
        let mut acc = PlanAccum::new();
        assert_eq!(acc.overlap_efficiency(), None, "no exchange measured yet");
        acc.record_overlap(6, 0.03, 0.01);
        assert_eq!(acc.prefetch_issued, 6);
        assert!((acc.comm_hidden_secs - 0.03).abs() < 1e-12);
        assert!((acc.comm_exposed_secs - 0.01).abs() < 1e-12);
        let eff = acc.overlap_efficiency().unwrap();
        assert!((eff - 0.75).abs() < 1e-9, "hidden/(hidden+exposed) = {eff}");
        let mut merged = PlanAccum::new();
        merged.merge(&acc);
        merged.merge(&acc);
        assert_eq!(merged.prefetch_issued, 12);
        assert!((merged.comm_hidden_secs - 0.06).abs() < 1e-12);
        assert!((merged.comm_exposed_secs - 0.02).abs() < 1e-12);
        // A synchronous run measures only exposed time: efficiency 0.
        let mut sync = PlanAccum::new();
        sync.record_overlap(0, 0.0, 0.02);
        assert_eq!(sync.overlap_efficiency(), Some(0.0));
    }

    #[test]
    fn ledger_merges() {
        let mut a = CommLedger::new();
        a.record_factor_exchange(100);
        let mut b = CommLedger::new();
        b.record_core_allreduce(50);
        a.merge(&b);
        assert_eq!(a.total_bytes(), 150);
        assert_eq!(a.events, 2);
    }
}
