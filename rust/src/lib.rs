//! # fasttucker
//!
//! A reproduction of **cuFastTucker** (Li, 2022): a compact stochastic
//! strategy for large-scale sparse Tucker decomposition, built as a
//! three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: datasets, sampling, the
//!   order-N reference engine, four baseline algorithms, the multi-device
//!   partition scheduler, metrics, the shared scalar/batched kernel layer
//!   ([`kernel`]), and the step runtime that executes the AOT-compiled
//!   JAX step functions (natively lowered to [`kernel`] on this offline
//!   build).
//! * **L2** (`python/compile/model.py`) — the order-3 SGD step as a JAX
//!   graph, lowered once to HLO text in `artifacts/`.
//! * **L1** (`python/compile/kernels/fasttucker.py`) — the Thm-1/2
//!   contraction as a Pallas kernel.
//!
//! Python never runs at training time; the binary is self-contained once
//! `make artifacts` has produced the HLO files.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every paper table/figure to a bench target.

// Every `unsafe` operation inside an `unsafe fn` must sit in an explicit
// `unsafe {}` block with its own SAFETY comment (the fn-level contract
// covers the caller, not the body) — enforced crate-wide, audited by
// `analysis::lint`.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod util;
pub mod tensor;
pub mod data;
pub mod kruskal;
pub mod model;
pub mod kernel;
pub mod algo;
pub mod sched;
pub mod parallel;
pub mod analysis;
pub mod metrics;
pub mod config;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod cli;
pub mod bench_support;

pub use tensor::SparseTensor;
pub use model::TuckerModel;
pub use coordinator::trainer::{Trainer, TrainOptions};
