//! Per-mode slice grouping (CSF-style access path).
//!
//! [`ModeSlices`] groups the nonzeros of a [`SparseTensor`] by their index
//! in one mode, CSR-style: `offsets[i]..offsets[i+1]` are positions into
//! `nz_ids` listing the nonzeros whose mode-`n` coordinate is `i`. This is
//! the access pattern P-Tucker's row-wise ALS and Vest's column-wise CCD
//! need (`(Ω_M^(n))_i` in the paper's notation), and what the paper's CSF
//! citation (Smith & Karypis) provides on real hardware.

use crate::tensor::SparseTensor;

/// CSR-style grouping of nonzeros by one mode's coordinate.
#[derive(Clone, Debug)]
pub struct ModeSlices {
    mode: usize,
    offsets: Vec<usize>,
    nz_ids: Vec<u32>,
}

impl ModeSlices {
    /// Build the grouping for `mode` with a counting sort — O(nnz + I_n).
    pub fn build(t: &SparseTensor, mode: usize) -> Self {
        assert!(mode < t.order(), "mode {mode} out of range");
        let dim = t.dims()[mode];
        let mut counts = vec![0usize; dim + 1];
        for k in 0..t.nnz() {
            counts[t.index(k)[mode] as usize + 1] += 1;
        }
        for i in 0..dim {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut nz_ids = vec![0u32; t.nnz()];
        for k in 0..t.nnz() {
            let i = t.index(k)[mode] as usize;
            nz_ids[cursor[i]] = k as u32;
            cursor[i] += 1;
        }
        ModeSlices { mode, offsets, nz_ids }
    }

    pub fn mode(&self) -> usize {
        self.mode
    }

    /// Nonzero ids whose mode coordinate equals `i`.
    #[inline]
    pub fn slice(&self, i: usize) -> &[u32] {
        &self.nz_ids[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Number of distinct rows (the mode's dimension).
    pub fn n_rows(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of nonzeros in row `i` — `|(Ω_M^(n))_i|`.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }

    /// Rows that actually have nonzeros (skip empty rows in ALS sweeps).
    pub fn nonempty_rows(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.n_rows()).filter(|&i| self.row_nnz(i) > 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::propcheck::forall;
    use crate::util::Rng;

    fn tiny() -> SparseTensor {
        SparseTensor::new(
            vec![3, 4],
            vec![2, 0, 0, 1, 2, 3, 0, 1],
            vec![10.0, 20.0, 30.0, 40.0],
        )
        .unwrap()
    }

    #[test]
    fn groups_by_mode0() {
        let t = tiny();
        let s = ModeSlices::build(&t, 0);
        assert_eq!(s.slice(0), &[1, 3]);
        assert_eq!(s.slice(1), &[]);
        assert_eq!(s.slice(2), &[0, 2]);
        assert_eq!(s.n_rows(), 3);
    }

    #[test]
    fn groups_by_mode1() {
        let t = tiny();
        let s = ModeSlices::build(&t, 1);
        assert_eq!(s.slice(0), &[0]);
        assert_eq!(s.slice(1), &[1, 3]);
        assert_eq!(s.slice(3), &[2]);
    }

    #[test]
    fn row_nnz_and_nonempty() {
        let t = tiny();
        let s = ModeSlices::build(&t, 0);
        assert_eq!(s.row_nnz(0), 2);
        assert_eq!(s.row_nnz(1), 0);
        let ne: Vec<usize> = s.nonempty_rows().collect();
        assert_eq!(ne, vec![0, 2]);
    }

    #[test]
    fn prop_partition_is_exact() {
        // Every nonzero appears exactly once, in the right slice.
        forall("mode slices partition nonzeros", 32, |rng| {
            let order = 2 + rng.gen_range(3);
            let dims: Vec<usize> = (0..order).map(|_| 2 + rng.gen_range(8)).collect();
            let nnz = 1 + rng.gen_range(200);
            let t = random_tensor(rng, &dims, nnz);
            for mode in 0..order {
                let s = ModeSlices::build(&t, mode);
                let mut seen = vec![false; t.nnz()];
                for i in 0..s.n_rows() {
                    for &k in s.slice(i) {
                        assert_eq!(t.index(k as usize)[mode] as usize, i);
                        assert!(!seen[k as usize], "duplicate nonzero id");
                        seen[k as usize] = true;
                    }
                }
                assert!(seen.iter().all(|&x| x));
            }
        });
    }

    fn random_tensor(rng: &mut Rng, dims: &[usize], nnz: usize) -> SparseTensor {
        synth::random_uniform(rng, dims, nnz, 1.0, 5.0)
    }
}
