//! Small dense tensor, row-major generalized layout (last mode fastest is
//! NOT used — we use mode-0 fastest to match `indexing::dense_index`).
//! Used for: the dense Tucker core `G` of the baselines, and tiny oracle
//! reconstructions in tests.

use crate::tensor::indexing;

/// Dense order-N tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseTensor {
    dims: Vec<usize>,
    data: Vec<f32>,
}

impl DenseTensor {
    pub fn zeros(dims: Vec<usize>) -> Self {
        let len = dims.iter().product();
        DenseTensor { dims, data: vec![0.0; len] }
    }

    pub fn from_data(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        DenseTensor { dims, data }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn order(&self) -> usize {
        self.dims.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, coords: &[u32]) -> f32 {
        self.data[indexing::dense_index(coords, &self.dims)]
    }

    #[inline]
    pub fn set(&mut self, coords: &[u32], v: f32) {
        self.data[indexing::dense_index(coords, &self.dims)] = v;
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f32 {
        (self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_set_get() {
        let mut t = DenseTensor::zeros(vec![2, 3, 4]);
        assert_eq!(t.len(), 24);
        t.set(&[1, 2, 3], 7.5);
        assert_eq!(t.get(&[1, 2, 3]), 7.5);
        assert_eq!(t.get(&[0, 0, 0]), 0.0);
    }

    #[test]
    fn from_data_checks_len() {
        let t = DenseTensor::from_data(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.get(&[1, 0]), 2.0); // mode-0 fastest layout
        assert_eq!(t.get(&[0, 1]), 3.0);
    }

    #[test]
    #[should_panic]
    fn from_data_wrong_len_panics() {
        DenseTensor::from_data(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn frob_norm() {
        let t = DenseTensor::from_data(vec![2], vec![3.0, 4.0]);
        assert!((t.frob_norm() - 5.0).abs() < 1e-6);
    }
}
