//! Sparse tensor storage and the matricization/vectorization index algebra
//! of the paper's Table 1.
//!
//! * [`coo`] — the canonical COO container for HOHDST data (order-N,
//!   u32 indices, f32 values).
//! * [`csf`] — per-mode CSR-like slice grouping (the access pattern the
//!   paper's CSF citation provides): for a fixed mode `n`, all nonzeros
//!   sharing a row index `i_n`, used by the ALS/CCD baselines.
//! * [`indexing`] — the bijections between tensor multi-indices and the
//!   `n`-mode matricization/vectorization linear indices.
//! * [`dense`] — a small dense tensor, used for oracles in tests and the
//!   dense-core baselines.

pub mod coo;
pub mod csf;
pub mod indexing;
pub mod dense;

pub use coo::SparseTensor;
pub use csf::ModeSlices;
pub use dense::DenseTensor;
