//! Matricization / vectorization index algebra (paper Table 1).
//!
//! For an order-N tensor with dims `I_1..I_N`, the `n`-mode matricization
//! `X^(n)` maps entry `(i_1..i_N)` to row `i_n` and column
//! `j = 1 + Σ_{k≠n} (i_k - 1) · Π_{m<k, m≠n} I_m` (paper's 1-based form);
//! we use the equivalent 0-based `j = Σ_{k≠n} i_k · stride_k`.
//! The `n`-mode vectorization linearizes `(i, j) -> j · I_n + i`.
//!
//! These bijections are what the multi-GPU partitioner and the dense-core
//! baselines navigate by; property tests pin them against each other.

/// Column strides of the `n`-mode matricization for `dims`.
///
/// `strides[k]` is the contribution multiplier of coordinate `i_k` to the
/// column index (0 for `k == n`, which indexes the row instead).
pub fn unfold_strides(dims: &[usize], n: usize) -> Vec<usize> {
    let mut strides = vec![0usize; dims.len()];
    let mut acc = 1usize;
    for k in 0..dims.len() {
        if k == n {
            continue;
        }
        strides[k] = acc;
        acc *= dims[k];
    }
    strides
}

/// Column index of `coords` in the `n`-mode matricization.
#[inline]
pub fn unfold_col(coords: &[u32], strides: &[usize], n: usize) -> usize {
    let mut j = 0usize;
    for k in 0..coords.len() {
        if k != n {
            j += coords[k] as usize * strides[k];
        }
    }
    j
}

/// Number of columns of the `n`-mode matricization: `Π_{k≠n} I_k`.
pub fn unfold_ncols(dims: &[usize], n: usize) -> usize {
    dims.iter()
        .enumerate()
        .filter(|(k, _)| *k != n)
        .map(|(_, &d)| d)
        .product()
}

/// `n`-mode vectorization linear index of `(row i_n, col j)`: `j·I_n + i_n`.
#[inline]
pub fn vec_index(row: usize, col: usize, i_n: usize) -> usize {
    col * i_n + row
}

/// Invert [`unfold_col`]: recover all coordinates except mode `n` from a
/// column index. `coords[n]` is left untouched.
pub fn col_to_coords(mut j: usize, dims: &[usize], n: usize, coords: &mut [u32]) {
    for k in 0..dims.len() {
        if k == n {
            continue;
        }
        coords[k] = (j % dims[k]) as u32;
        j /= dims[k];
    }
    debug_assert_eq!(j, 0);
}

/// Row-major linear index into a dense tensor of shape `dims`.
#[inline]
pub fn dense_index(coords: &[u32], dims: &[usize]) -> usize {
    let mut idx = 0usize;
    for k in (0..dims.len()).rev() {
        idx = idx * dims[k] + coords[k] as usize;
    }
    idx
}

/// Invert [`dense_index`].
pub fn dense_coords(mut idx: usize, dims: &[usize], coords: &mut [u32]) {
    for k in 0..dims.len() {
        coords[k] = (idx % dims[k]) as u32;
        idx /= dims[k];
    }
    debug_assert_eq!(idx, 0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn strides_skip_mode() {
        // dims [3,4,5], mode 1: strides over modes {0,2} = [1, 0, 3].
        assert_eq!(unfold_strides(&[3, 4, 5], 1), vec![1, 0, 3]);
        assert_eq!(unfold_strides(&[3, 4, 5], 0), vec![0, 1, 4]);
    }

    #[test]
    fn ncols_excludes_mode() {
        assert_eq!(unfold_ncols(&[3, 4, 5], 0), 20);
        assert_eq!(unfold_ncols(&[3, 4, 5], 1), 15);
        assert_eq!(unfold_ncols(&[3, 4, 5], 2), 12);
    }

    #[test]
    fn col_roundtrip_small() {
        let dims = [3usize, 4, 5];
        for n in 0..3 {
            let strides = unfold_strides(&dims, n);
            let mut seen = std::collections::HashSet::new();
            let mut coords = [0u32; 3];
            for i0 in 0..3u32 {
                for i1 in 0..4u32 {
                    for i2 in 0..5u32 {
                        let c = [i0, i1, i2];
                        let j = unfold_col(&c, &strides, n);
                        assert!(j < unfold_ncols(&dims, n));
                        col_to_coords(j, &dims, n, &mut coords);
                        for k in 0..3 {
                            if k != n {
                                assert_eq!(coords[k], c[k]);
                            }
                        }
                        seen.insert((c[n], j));
                    }
                }
            }
            // (row, col) pairs are unique: the matricization is a bijection.
            assert_eq!(seen.len(), 60);
        }
    }

    #[test]
    fn prop_unfold_col_bijective() {
        forall("unfold col bijective", 64, |rng| {
            let order = 2 + rng.gen_range(4); // 2..=5
            let dims: Vec<usize> = (0..order).map(|_| 1 + rng.gen_range(6)).collect();
            let n = rng.gen_range(order);
            let strides = unfold_strides(&dims, n);
            let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d) as u32).collect();
            let j = unfold_col(&coords, &strides, n);
            let mut rec = vec![0u32; order];
            col_to_coords(j, &dims, n, &mut rec);
            for k in 0..order {
                if k != n {
                    assert_eq!(rec[k], coords[k], "mode {k}");
                }
            }
        });
    }

    #[test]
    fn prop_dense_index_roundtrip() {
        forall("dense index roundtrip", 64, |rng| {
            let order = 1 + rng.gen_range(5);
            let dims: Vec<usize> = (0..order).map(|_| 1 + rng.gen_range(7)).collect();
            let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d) as u32).collect();
            let idx = dense_index(&coords, &dims);
            assert!(idx < dims.iter().product::<usize>());
            let mut rec = vec![0u32; order];
            dense_coords(idx, &dims, &mut rec);
            assert_eq!(rec, coords);
        });
    }

    #[test]
    fn vec_index_matches_paper_definition() {
        // k = (j-1)I_n + i in 1-based == j*I_n + i in 0-based.
        assert_eq!(vec_index(2, 3, 10), 32);
        assert_eq!(vec_index(0, 0, 10), 0);
    }
}
