//! COO sparse tensor: the HOHDST container every algorithm consumes.
//!
//! Indices are stored as one flat `Vec<u32>` of length `nnz * order` in
//! sample-major layout (all `N` coordinates of nonzero `k` are contiguous),
//! which is the coalesced layout the paper uses for the nonzero stream on
//! GPU: one memory request fetches a whole sample's coordinates.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::element::Element;
use crate::util::error::{bail, Result};

/// Process-global revision counter: every constructed or mutated
/// [`SparseTensor`] gets a fresh, unique revision (see
/// [`SparseTensor::revision`]).
static NEXT_REVISION: AtomicU64 = AtomicU64::new(1);

fn fresh_revision() -> u64 {
    NEXT_REVISION.fetch_add(1, Ordering::Relaxed)
}

/// An order-N sparse tensor in coordinate format.
///
/// The value type `V` is any sealed [`Element`] (ISSUE 10): the default
/// `f32` is the paper's input precision and what every engine consumes;
/// `f64` instantiations carry full-precision inputs through the same
/// container (the factor storage precision is a separate axis — see
/// [`crate::model::factors::Matrix`]).
#[derive(Clone, Debug)]
pub struct SparseTensor<V: Element = f32> {
    dims: Vec<usize>,
    /// Flat `nnz * order` coordinate array, sample-major.
    indices: Vec<u32>,
    values: Vec<V>,
    /// Content revision (ISSUE 9): a process-unique id assigned at
    /// construction and re-assigned by every mutation ([`Self::append`]).
    /// Engine caches (planner decisions, block partitions, device grids)
    /// fingerprint on it so a long-lived engine can never reuse state
    /// derived from different nonzeros — even when `nnz` and `dims`
    /// coincide. Clones share the revision (identical content); the
    /// over-approximation is one-sided: a fresh id may force a redundant
    /// rebuild, never a stale reuse.
    revision: u64,
}

impl<V: Element> SparseTensor<V> {
    /// Build from parts, validating bounds.
    pub fn new(dims: Vec<usize>, indices: Vec<u32>, values: Vec<V>) -> Result<Self> {
        let order = dims.len();
        if order == 0 {
            bail!("tensor order must be >= 1");
        }
        if indices.len() != values.len() * order {
            bail!(
                "index/value length mismatch: {} indices, {} values, order {}",
                indices.len(),
                values.len(),
                order
            );
        }
        for d in &dims {
            if *d == 0 {
                bail!("zero-sized mode");
            }
            if *d > u32::MAX as usize {
                bail!("mode size {} exceeds u32 index range", d);
            }
        }
        for (k, chunk) in indices.chunks_exact(order).enumerate() {
            for (n, (&i, &d)) in chunk.iter().zip(dims.iter()).enumerate() {
                if i as usize >= d {
                    bail!("nonzero {k}: index {i} out of bounds for mode {n} (dim {d})");
                }
            }
        }
        Ok(SparseTensor { dims, indices, values, revision: fresh_revision() })
    }

    /// Build without bounds checks (generators that construct indices by
    /// `gen_range(dim)` are safe by construction; skips an O(nnz·N) pass).
    pub fn new_unchecked(dims: Vec<usize>, indices: Vec<u32>, values: Vec<V>) -> Self {
        debug_assert_eq!(indices.len(), values.len() * dims.len());
        SparseTensor { dims, indices, values, revision: fresh_revision() }
    }

    /// An empty tensor with the given mode sizes.
    pub fn empty(dims: Vec<usize>) -> Self {
        SparseTensor {
            dims,
            indices: Vec::new(),
            values: Vec::new(),
            revision: fresh_revision(),
        }
    }

    /// Content revision: process-unique per construction/mutation, shared
    /// by clones. Cache fingerprints include it so appended or swapped
    /// nonzeros invalidate exactly the state derived from them.
    #[inline]
    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Append nonzeros in the flat sample-major layout, validating shape
    /// and bounds (the streaming-ingest entry point). Dims are fixed at
    /// construction; `indices.len()` must be `values.len() * order`. On
    /// success the tensor gets a fresh [`Self::revision`]; on error it is
    /// untouched.
    pub fn append(&mut self, indices: &[u32], values: &[V]) -> Result<()> {
        let order = self.order();
        if indices.len() != values.len() * order {
            bail!(
                "append: index/value length mismatch: {} indices, {} values, order {}",
                indices.len(),
                values.len(),
                order
            );
        }
        for (k, chunk) in indices.chunks_exact(order).enumerate() {
            for (n, (&i, &d)) in chunk.iter().zip(self.dims.iter()).enumerate() {
                if i as usize >= d {
                    bail!(
                        "append: nonzero {k}: index {i} out of bounds for mode {n} (dim {d})"
                    );
                }
            }
        }
        self.indices.extend_from_slice(indices);
        self.values.extend_from_slice(values);
        self.revision = fresh_revision();
        Ok(())
    }

    /// Append every nonzero of `other` (an arrival batch). The dims must
    /// match exactly — a batch shaped for a different tensor is an error,
    /// not a silent re-index.
    pub fn append_tensor(&mut self, other: &SparseTensor<V>) -> Result<()> {
        if self.dims != other.dims {
            bail!(
                "append_tensor: dims mismatch: {:?} vs batch {:?}",
                self.dims,
                other.dims
            );
        }
        // Bounds already validated against identical dims at `other`'s
        // construction; skip the O(nnz·N) re-check.
        self.indices.extend_from_slice(&other.indices);
        self.values.extend_from_slice(&other.values);
        self.revision = fresh_revision();
        Ok(())
    }

    pub fn order(&self) -> usize {
        self.dims.len()
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn values(&self) -> &[V] {
        &self.values
    }

    pub fn indices_flat(&self) -> &[u32] {
        &self.indices
    }

    /// Coordinates of nonzero `k`.
    #[inline]
    pub fn index(&self, k: usize) -> &[u32] {
        let n = self.order();
        &self.indices[k * n..(k + 1) * n]
    }

    /// Value of nonzero `k`.
    #[inline]
    pub fn value(&self, k: usize) -> V {
        self.values[k]
    }

    /// Iterate `(coords, value)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (&[u32], V)> + '_ {
        let n = self.order();
        self.indices
            .chunks_exact(n)
            .zip(self.values.iter().copied())
    }

    /// Density |Ω| / ∏ I_n (useful for logging; HOHDST data is ~1e-6).
    pub fn density(&self) -> f64 {
        let total: f64 = self.dims.iter().map(|&d| d as f64).product();
        self.nnz() as f64 / total
    }

    /// Mean of the stored values (accumulated wide).
    pub fn mean_value(&self) -> V {
        if self.values.is_empty() {
            return V::ZERO;
        }
        V::from_f64(self.values.iter().map(|&v| v.to_f64()).sum::<f64>() / self.nnz() as f64)
    }

    /// Take a subset of nonzeros by id (used by the block partitioner and
    /// train/test splitting).
    pub fn gather(&self, ids: &[usize]) -> SparseTensor<V> {
        let n = self.order();
        let mut indices = Vec::with_capacity(ids.len() * n);
        let mut values = Vec::with_capacity(ids.len());
        for &k in ids {
            indices.extend_from_slice(self.index(k));
            values.push(self.values[k]);
        }
        SparseTensor {
            dims: self.dims.clone(),
            indices,
            values,
            revision: fresh_revision(),
        }
    }

    /// A copy with `delta` added to every value (mean-centering for
    /// ratings data: train on `x - mean`, predict `x̂ + mean`).
    pub fn with_shifted_values(&self, delta: V) -> SparseTensor<V> {
        SparseTensor {
            dims: self.dims.clone(),
            indices: self.indices.clone(),
            values: self.values.iter().map(|&v| v + delta).collect(),
            revision: fresh_revision(),
        }
    }

    /// Memory footprint of the container in bytes (for the paper's
    /// space-overhead comparisons).
    pub fn footprint_bytes(&self) -> usize {
        self.indices.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<V>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SparseTensor {
        // 3x4x5 with 3 nonzeros.
        SparseTensor::new(
            vec![3, 4, 5],
            vec![0, 0, 0, 1, 2, 3, 2, 3, 4],
            vec![1.0, 2.0, 3.0],
        )
        .unwrap()
    }

    #[test]
    fn construction_and_accessors() {
        let t = tiny();
        assert_eq!(t.order(), 3);
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.dims(), &[3, 4, 5]);
        assert_eq!(t.index(1), &[1, 2, 3]);
        assert_eq!(t.value(2), 3.0);
    }

    #[test]
    fn rejects_out_of_bounds() {
        let r = SparseTensor::new(vec![2, 2], vec![0, 2], vec![1.0]);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_length_mismatch() {
        let r = SparseTensor::new(vec![2, 2], vec![0, 1, 1], vec![1.0]);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_zero_dim() {
        let r = SparseTensor::new(vec![2, 0], vec![], vec![]);
        assert!(r.is_err());
    }

    #[test]
    fn iter_yields_all() {
        let t = tiny();
        let collected: Vec<_> = t.iter().map(|(ix, v)| (ix.to_vec(), v)).collect();
        assert_eq!(collected.len(), 3);
        assert_eq!(collected[0], (vec![0, 0, 0], 1.0));
        assert_eq!(collected[2], (vec![2, 3, 4], 3.0));
    }

    #[test]
    fn gather_subsets() {
        let t = tiny();
        let g = t.gather(&[2, 0]);
        assert_eq!(g.nnz(), 2);
        assert_eq!(g.index(0), &[2, 3, 4]);
        assert_eq!(g.value(1), 1.0);
        assert_eq!(g.dims(), t.dims());
    }

    #[test]
    fn density_and_mean() {
        let t = tiny();
        assert!((t.density() - 3.0 / 60.0).abs() < 1e-12);
        assert!((t.mean_value() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn footprint_counts_indices_and_values() {
        let t = tiny();
        assert_eq!(t.footprint_bytes(), 9 * 4 + 3 * 4);
    }

    #[test]
    fn revisions_are_unique_per_construction_and_shared_by_clones() {
        let a = tiny();
        let b = tiny();
        assert_ne!(a.revision(), b.revision());
        let c = a.clone();
        assert_eq!(a.revision(), c.revision());
        // Derived tensors have different content -> fresh revisions.
        assert_ne!(a.gather(&[0]).revision(), a.revision());
        assert_ne!(a.with_shifted_values(1.0).revision(), a.revision());
    }

    #[test]
    fn append_grows_and_bumps_revision() {
        let mut t = tiny();
        let r0 = t.revision();
        t.append(&[1, 1, 1, 2, 2, 2], &[4.0, 5.0]).unwrap();
        assert_eq!(t.nnz(), 5);
        assert_eq!(t.index(3), &[1, 1, 1]);
        assert_eq!(t.value(4), 5.0);
        assert_ne!(t.revision(), r0);
    }

    #[test]
    fn append_rejects_bad_batches_untouched() {
        let mut t = tiny();
        let r0 = t.revision();
        // Length mismatch.
        assert!(t.append(&[0, 0], &[1.0]).is_err());
        // Out-of-bounds coordinate (mode 0 has dim 3).
        assert!(t.append(&[3, 0, 0], &[1.0]).is_err());
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.revision(), r0);
    }

    #[test]
    fn f64_instantiation_carries_wide_values() {
        // ISSUE 10: the container genericizes over the sealed Element
        // types — an f64 tensor holds values past f32 precision intact.
        let wide_val = 1.0f64 + 1.0e-12;
        let t = SparseTensor::<f64>::new(vec![2, 2], vec![0, 1], vec![wide_val]).unwrap();
        assert_eq!(t.value(0), wide_val);
        assert_ne!(t.value(0) as f32 as f64, wide_val);
        assert_eq!(t.mean_value(), wide_val);
        let shifted = t.with_shifted_values(1.0);
        assert_eq!(shifted.value(0), wide_val + 1.0);
        assert_eq!(t.footprint_bytes(), 2 * 4 + 8);
    }

    #[test]
    fn append_tensor_merges_and_checks_dims() {
        let mut t = tiny();
        let batch =
            SparseTensor::new(vec![3, 4, 5], vec![2, 0, 1], vec![9.0]).unwrap();
        t.append_tensor(&batch).unwrap();
        assert_eq!(t.nnz(), 4);
        assert_eq!(t.index(3), &[2, 0, 1]);
        let wrong = SparseTensor::empty(vec![3, 4]);
        assert!(t.append_tensor(&wrong).is_err());
    }
}
