//! Synthetic HOHDST generators.
//!
//! Two families:
//! * [`random_uniform`] — structureless noise tensors (used by unit tests
//!   and the pure-throughput benches, matching the paper's Table 5
//!   synthesis sets whose values are uniform in [1, 5]).
//! * [`planted_tucker`] — tensors whose values come from a ground-truth
//!   low-rank Tucker model (Kruskal core) plus Gaussian noise, so accuracy
//!   experiments have a recoverable signal and a known noise floor.

use crate::kruskal::KruskalCore;
use crate::model::factors::FactorMatrices;
use crate::tensor::SparseTensor;
use crate::util::Rng;

/// Uniform random tensor: `nnz` coordinates drawn iid (duplicates allowed,
/// as in real recommender logs re-rating), values uniform in `[lo, hi]`.
pub fn random_uniform(
    rng: &mut Rng,
    dims: &[usize],
    nnz: usize,
    lo: f32,
    hi: f32,
) -> SparseTensor {
    let order = dims.len();
    let mut indices = Vec::with_capacity(nnz * order);
    let mut values = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        for &d in dims {
            indices.push(rng.gen_range(d) as u32);
        }
        values.push(lo + (hi - lo) * rng.uniform());
    }
    SparseTensor::new_unchecked(dims.to_vec(), indices, values)
}

/// Parameters for the planted-model generator.
#[derive(Clone, Debug)]
pub struct PlantedSpec {
    pub dims: Vec<usize>,
    pub nnz: usize,
    /// Factor rank J (same for every mode, like the paper's experiments).
    pub j: usize,
    /// Kruskal core rank R_core of the ground truth.
    pub r_core: usize,
    /// Std-dev of additive Gaussian observation noise.
    pub noise: f32,
    /// Clamp values into `[lo, hi]` if set (ratings-style data).
    pub clamp: Option<(f32, f32)>,
}

/// Output of [`planted_tucker`]: the observations plus the ground truth
/// (handy for oracle checks; the noise floor is `spec.noise`).
pub struct Planted {
    pub tensor: SparseTensor,
    pub truth_factors: FactorMatrices,
    pub truth_core: KruskalCore,
}

/// Generate a sparse tensor whose values are
/// `x = Σ_r Π_n (a^(n)_{i_n} · b^(n)_r) + ε`.
pub fn planted_tucker(rng: &mut Rng, spec: &PlantedSpec) -> Planted {
    let order = spec.dims.len();
    let scale = (1.0 / (spec.j as f32)).sqrt();
    // Ratings-style data (clamp set) gets *biased* factors — entries
    // `scale·(1 + 0.6·N(0,1))` — giving the dominant rank-1
    // popularity/bias structure real ratings matrices show; unclamped
    // data keeps plain zero-mean Gaussian factors.
    let factors = if spec.clamp.is_some() {
        let mats = spec
            .dims
            .iter()
            .map(|&d| {
                let data: Vec<f32> = (0..d * spec.j)
                    .map(|_| scale * (1.0 + 0.6 * rng.normal()))
                    .collect();
                crate::model::factors::Matrix::from_data(d, spec.j, data)
            })
            .collect();
        FactorMatrices::from_mats(mats)
    } else {
        FactorMatrices::random(rng, &spec.dims, spec.j, scale)
    };
    let core = KruskalCore::random(rng, order, spec.j, spec.r_core, 1.0);

    let mut indices = Vec::with_capacity(spec.nnz * order);
    let mut values = Vec::with_capacity(spec.nnz);
    let mut coords = vec![0u32; order];
    // Clamped data: empirically recenter/rescale the planted signal into
    // the middle half of the range so the clamp rarely saturates —
    // otherwise the low-rank structure is destroyed and nothing is
    // learnable from the generated tensor.
    let (offset, gain) = match spec.clamp {
        Some((lo, hi)) => {
            let probes = 2000.min(spec.nnz.max(16));
            let mut sample = Vec::with_capacity(probes);
            for _ in 0..probes {
                for (n, &d) in spec.dims.iter().enumerate() {
                    coords[n] = rng.gen_range(d) as u32;
                }
                sample.push(predict_planted(&factors, &core, &coords));
            }
            let m = sample.iter().sum::<f32>() / probes as f32;
            let s = (sample.iter().map(|v| (v - m) * (v - m)).sum::<f32>()
                / probes as f32)
                .sqrt()
                .max(1e-6);
            let gain = 0.25 * (hi - lo) / s;
            (0.5 * (lo + hi) - gain * m, gain)
        }
        None => (0.0, 1.0),
    };
    for _ in 0..spec.nnz {
        for (n, &d) in spec.dims.iter().enumerate() {
            coords[n] = rng.gen_range(d) as u32;
        }
        let mut x = offset + gain * predict_planted(&factors, &core, &coords);
        x += spec.noise * rng.normal();
        if let Some((lo, hi)) = spec.clamp {
            x = x.clamp(lo, hi);
        }
        indices.extend_from_slice(&coords);
        values.push(x);
    }
    Planted {
        tensor: SparseTensor::new_unchecked(spec.dims.clone(), indices, values),
        truth_factors: factors,
        truth_core: core,
    }
}

/// Ground-truth prediction for one coordinate (linear Thm-1 path).
///
/// Compat re-export: the oracle now lives in [`crate::kruskal::predict`]
/// (the generator *calls* the model layer, never the reverse — ISSUE 9
/// layering fix); historical imports keep working through this alias.
pub use crate::kruskal::predict::predict_one as predict_planted;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn random_uniform_respects_bounds() {
        let mut rng = Rng::new(1);
        let t = random_uniform(&mut rng, &[10, 20, 30], 500, 1.0, 5.0);
        assert_eq!(t.nnz(), 500);
        for (ix, v) in t.iter() {
            assert!(ix[0] < 10 && ix[1] < 20 && ix[2] < 30);
            assert!((1.0..=5.0).contains(&v));
        }
    }

    #[test]
    fn planted_values_match_truth_when_noiseless() {
        let mut rng = Rng::new(2);
        let spec = PlantedSpec {
            dims: vec![20, 30, 25],
            nnz: 300,
            j: 4,
            r_core: 2,
            noise: 0.0,
            clamp: None,
        };
        let p = planted_tucker(&mut rng, &spec);
        for k in 0..p.tensor.nnz() {
            let want = predict_planted(&p.truth_factors, &p.truth_core, p.tensor.index(k));
            assert!((p.tensor.value(k) - want).abs() < 1e-5);
        }
    }

    #[test]
    fn clamp_applies() {
        let mut rng = Rng::new(3);
        let spec = PlantedSpec {
            dims: vec![10, 10, 10],
            nnz: 200,
            j: 4,
            r_core: 4,
            noise: 3.0,
            clamp: Some((1.0, 5.0)),
        };
        let p = planted_tucker(&mut rng, &spec);
        for (_, v) in p.tensor.iter() {
            assert!((1.0..=5.0).contains(&v));
        }
    }

    #[test]
    fn prop_planted_any_order() {
        forall("planted generator valid for orders 2..6", 12, |rng| {
            let order = 2 + rng.gen_range(5);
            let dims: Vec<usize> = (0..order).map(|_| 4 + rng.gen_range(10)).collect();
            let spec = PlantedSpec {
                dims: dims.clone(),
                nnz: 50,
                j: 2 + rng.gen_range(3),
                r_core: 1 + rng.gen_range(3),
                noise: 0.1,
                clamp: None,
            };
            let p = planted_tucker(rng, &spec);
            assert_eq!(p.tensor.order(), order);
            assert_eq!(p.tensor.nnz(), 50);
            assert!(p.tensor.values().iter().all(|v| v.is_finite()));
        });
    }
}
