//! Named dataset registry used by experiment drivers and benches.
//!
//! Mirrors the paper's Tables 4 (real) and 5 (synthesis) at laptop scale:
//! mode-size *ratios* and order are preserved, absolute sizes and nonzero
//! counts are scaled down so every experiment runs in seconds on a CPU.
//! Real `.tns` files, when available, can be loaded with `Dataset::File`.

use std::path::PathBuf;

use crate::util::error::{anyhow, bail, Result};

use crate::data::synth::{self, PlantedSpec};
use crate::tensor::SparseTensor;
use crate::util::Rng;

/// A named dataset the drivers can instantiate.
#[derive(Clone, Debug)]
pub enum Dataset {
    /// Planted low-rank synthetic replica with a paper-shaped geometry.
    Planted(PlantedSpec),
    /// Structureless uniform tensor (paper's Table 5 synthesis sets).
    Uniform { dims: Vec<usize>, nnz: usize, lo: f32, hi: f32 },
    /// A `.tns` file on disk.
    File(PathBuf),
}

impl Dataset {
    /// Look up a dataset by name. `scale` multiplies mode sizes and nnz
    /// (1.0 = default laptop scale).
    pub fn by_name(name: &str, scale: f64) -> Result<Dataset> {
        let s = |x: usize| ((x as f64 * scale).round() as usize).max(4);
        Ok(match name {
            // Netflix: 480189 x 17770 x 2182, 99M nnz -> ~1/100 linear
            // scale, keeping the observations-per-user ratio (~200) so
            // the planted structure is statistically recoverable.
            "netflix-like" => Dataset::Planted(PlantedSpec {
                dims: vec![s(4802), s(1777), s(218)],
                nnz: s(1_000_000),
                j: 8,
                r_core: 4,
                noise: 0.3,
                clamp: Some((1.0, 5.0)),
            }),
            // Yahoo!Music: 1M x 625k x 3075, 250M nnz.
            "yahoo-like" => Dataset::Planted(PlantedSpec {
                dims: vec![s(10_010), s(6250), s(308)],
                nnz: s(2_500_000),
                j: 8,
                r_core: 4,
                noise: 0.5,
                clamp: Some((0.025, 5.0)),
            }),
            // Amazon Reviews: 4.8M x 1.8M x 1.8M, 1.7G nnz (scale test).
            "amazon-like" => Dataset::Planted(PlantedSpec {
                dims: vec![s(48_212), s(17_743), s(18_052)],
                nnz: s(4_000_000),
                j: 4,
                r_core: 4,
                noise: 0.5,
                clamp: Some((1.0, 5.0)),
            }),
            // Small versions for tests and quick examples.
            "tiny" => Dataset::Planted(PlantedSpec {
                dims: vec![60, 50, 40],
                nnz: 6_000,
                j: 4,
                r_core: 4,
                noise: 0.05,
                clamp: None,
            }),
            "small" => Dataset::Planted(PlantedSpec {
                dims: vec![300, 250, 200],
                nnz: 60_000,
                j: 8,
                r_core: 8,
                noise: 0.1,
                clamp: None,
            }),
            other => {
                // Table 5 synthesis sets: "synth-orderK[-nnzM]".
                if let Some(rest) = other.strip_prefix("synth-order") {
                    let mut parts = rest.split('-');
                    let order: usize = parts
                        .next()
                        .and_then(|p| p.parse().ok())
                        .ok_or_else(|| anyhow!("bad synth name {other}"))?;
                    if !(3..=10).contains(&order) {
                        bail!("synth order must be 3..=10, got {order}");
                    }
                    let nnz: usize = match parts.next() {
                        Some(p) => p.parse()?,
                        // Paper: order-3 1G, order-4 800M, order-5 600M,
                        // order-6..10 100M — scaled down by ~1e3.
                        None => match order {
                            3 => 1_000_000,
                            4 => 800_000,
                            5 => 600_000,
                            _ => 100_000,
                        },
                    };
                    let nnz = ((nnz as f64) * scale).round() as usize;
                    // Paper uses I = 10,000 per mode; scaled to 1,000.
                    let dim = s(1000);
                    Dataset::Uniform {
                        dims: vec![dim; order],
                        nnz: nnz.max(order),
                        lo: 1.0,
                        hi: 5.0,
                    }
                } else {
                    bail!("unknown dataset {other:?}");
                }
            }
        })
    }

    /// All registry names (for `--help` and the data generator CLI).
    pub fn names() -> &'static [&'static str] {
        &[
            "netflix-like",
            "yahoo-like",
            "amazon-like",
            "tiny",
            "small",
            "synth-order3",
            "synth-order4",
            "synth-order5",
            "synth-order6",
            "synth-order7",
            "synth-order8",
            "synth-order9",
            "synth-order10",
        ]
    }

    /// Materialize the dataset.
    pub fn build(&self, rng: &mut Rng) -> Result<SparseTensor> {
        Ok(match self {
            Dataset::Planted(spec) => synth::planted_tucker(rng, spec).tensor,
            Dataset::Uniform { dims, nnz, lo, hi } => {
                synth::random_uniform(rng, dims, *nnz, *lo, *hi)
            }
            Dataset::File(path) => crate::data::io::load_tns(path, None)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_resolve() {
        for name in Dataset::names() {
            Dataset::by_name(name, 1.0).unwrap();
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(Dataset::by_name("nope", 1.0).is_err());
    }

    #[test]
    fn tiny_builds() {
        let mut rng = Rng::new(1);
        let d = Dataset::by_name("tiny", 1.0).unwrap();
        let t = d.build(&mut rng).unwrap();
        assert_eq!(t.order(), 3);
        assert_eq!(t.nnz(), 6000);
    }

    #[test]
    fn synth_orders_have_right_order() {
        let mut rng = Rng::new(2);
        for order in [3usize, 5, 10] {
            let d = Dataset::by_name(&format!("synth-order{order}"), 0.01).unwrap();
            let t = d.build(&mut rng).unwrap();
            assert_eq!(t.order(), order);
        }
    }

    #[test]
    fn scale_shrinks() {
        let d1 = Dataset::by_name("netflix-like", 1.0).unwrap();
        let d2 = Dataset::by_name("netflix-like", 0.1).unwrap();
        match (d1, d2) {
            (Dataset::Planted(a), Dataset::Planted(b)) => {
                assert!(b.dims[0] < a.dims[0]);
                assert!(b.nnz < a.nnz);
            }
            _ => panic!("expected planted"),
        }
    }

    #[test]
    fn custom_synth_nnz() {
        let d = Dataset::by_name("synth-order4-5000", 1.0).unwrap();
        match d {
            Dataset::Uniform { nnz, dims, .. } => {
                assert_eq!(nnz, 5000);
                assert_eq!(dims.len(), 4);
            }
            _ => panic!(),
        }
    }
}
