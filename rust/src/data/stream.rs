//! Streaming COO ingest simulation: arrival batches drawn from the same
//! planted ground truth as the base tensor.
//!
//! Real recommender logs grow between retraining runs; the serving
//! session (ISSUE 9) models that as *arrival batches* appended to the
//! training [`SparseTensor`](crate::tensor::SparseTensor) between
//! epochs, at the session boundary. [`ArrivalSim`] holds a clone of a
//! [`Planted`] generator's ground truth and draws fresh observations
//! from it — same signal, same noise floor — so a warm-start epoch over
//! the grown tensor has a recoverable target and the
//! warm-start-beats-cold claim is measurable rather than assumed.
//!
//! Simplification, on purpose: clamped (ratings-style) arrivals clamp
//! the raw planted signal without the empirical offset/gain recentering
//! [`planted_tucker`](crate::data::synth::planted_tucker) applies to the
//! base tensor — the recentering constants are private to the one-shot
//! generator, and a mild distribution shift between the base data and
//! arrivals is itself realistic. Unclamped arrivals are drawn from the
//! identical distribution as the base tensor.
//!
//! Coordinates default to uniform per mode; [`ArrivalModel::Zipf`]
//! (ISSUE 10) skews them toward low ids with an inverse-CDF sampler, so
//! serving benches can measure what hot-row locality buys the
//! [`HotRowCache`](crate::serve::cache::HotRowCache).

use crate::data::synth::{predict_planted, Planted, PlantedSpec};
use crate::kruskal::KruskalCore;
use crate::model::factors::FactorMatrices;
use crate::tensor::SparseTensor;
use crate::util::Rng;

/// How arrival coordinates are drawn within each mode (ISSUE 10
/// satellite). Real serving traffic is heavily skewed — a few hot users
/// and items dominate — and the uniform model hides every cache/locality
/// effect that skew creates.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum ArrivalModel {
    /// Every index in a mode equally likely (the original behaviour).
    #[default]
    Uniform,
    /// Zipf-distributed indices: index `i` (0-based rank) is drawn with
    /// probability proportional to `1 / (i + 1)^exponent`. Low ids are
    /// the hot rows; `exponent` around 1.0 matches classic web/traffic
    /// skew, larger is spikier.
    Zipf { exponent: f64 },
}

/// Precomputed per-mode Zipf CDF: `cdf[i]` = P(index <= i). Sampling is
/// inverse-transform — one `uniform_f64` draw, then a binary search — so
/// arrival streams stay deterministic per seed, exactly like the
/// uniform path.
fn zipf_cdf(n: usize, exponent: f64) -> Vec<f64> {
    assert!(n > 0, "zipf CDF over an empty mode");
    assert!(
        exponent.is_finite() && exponent > 0.0,
        "zipf exponent must be finite and positive, got {exponent}"
    );
    let mut cdf = Vec::with_capacity(n);
    let mut total = 0.0f64;
    for i in 0..n {
        total += 1.0 / ((i + 1) as f64).powf(exponent);
        cdf.push(total);
    }
    // Normalize by the generalized harmonic number H_{n,s}; pin the last
    // entry to exactly 1.0 so the inverse transform can never fall off
    // the end on a draw of ~1.0.
    for c in cdf.iter_mut() {
        *c /= total;
    }
    cdf[n - 1] = 1.0;
    cdf
}

/// Inverse-transform draw from a precomputed CDF: the first index whose
/// cumulative mass reaches `u`.
fn sample_cdf(cdf: &[f64], u: f64) -> usize {
    cdf.partition_point(|&c| c < u).min(cdf.len() - 1)
}

/// Draws arrival batches from a planted ground truth.
#[derive(Clone, Debug)]
pub struct ArrivalSim {
    dims: Vec<usize>,
    truth_factors: FactorMatrices,
    truth_core: KruskalCore,
    noise: f32,
    clamp: Option<(f32, f32)>,
    /// Per-mode coordinate distribution for arrivals.
    model: ArrivalModel,
    /// Per-mode CDFs when `model` is Zipf (empty for Uniform).
    cdfs: Vec<Vec<f64>>,
    /// Total nonzeros generated so far, across all batches.
    generated: usize,
}

impl ArrivalSim {
    /// Build a simulator over `planted`'s ground truth, reusing the
    /// generator spec's noise level and clamp range.
    pub fn from_planted(planted: &Planted, spec: &PlantedSpec) -> Self {
        ArrivalSim {
            dims: spec.dims.clone(),
            truth_factors: planted.truth_factors.clone(),
            truth_core: planted.truth_core.clone(),
            noise: spec.noise,
            clamp: spec.clamp,
            model: ArrivalModel::Uniform,
            cdfs: Vec::new(),
            generated: 0,
        }
    }

    /// Builder: switch the per-mode coordinate distribution. Zipf CDFs
    /// are precomputed here, once per mode, so `next_batch` stays
    /// allocation-light.
    pub fn with_arrival_model(mut self, model: ArrivalModel) -> Self {
        self.model = model;
        self.cdfs = match model {
            ArrivalModel::Uniform => Vec::new(),
            ArrivalModel::Zipf { exponent } => {
                self.dims.iter().map(|&d| zipf_cdf(d, exponent)).collect()
            }
        };
        self
    }

    pub fn arrival_model(&self) -> ArrivalModel {
        self.model
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Total nonzeros produced so far.
    pub fn generated(&self) -> usize {
        self.generated
    }

    /// Draw one arrival batch of `nnz` fresh observations as its own
    /// tensor (append it with
    /// [`SparseTensor::append_tensor`](crate::tensor::SparseTensor::append_tensor)).
    pub fn next_batch(&mut self, rng: &mut Rng, nnz: usize) -> SparseTensor {
        let order = self.dims.len();
        let mut indices = Vec::with_capacity(nnz * order);
        let mut values = Vec::with_capacity(nnz);
        let mut coords = vec![0u32; order];
        for _ in 0..nnz {
            for (n, &d) in self.dims.iter().enumerate() {
                coords[n] = match self.model {
                    ArrivalModel::Uniform => rng.gen_range(d) as u32,
                    ArrivalModel::Zipf { .. } => {
                        sample_cdf(&self.cdfs[n], rng.uniform_f64()) as u32
                    }
                };
            }
            let mut x = predict_planted(&self.truth_factors, &self.truth_core, &coords);
            x += self.noise * rng.normal();
            if let Some((lo, hi)) = self.clamp {
                x = x.clamp(lo, hi);
            }
            indices.extend_from_slice(&coords);
            values.push(x);
        }
        self.generated += nnz;
        SparseTensor::new_unchecked(self.dims.clone(), indices, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::planted_tucker;

    fn setup(noise: f32, clamp: Option<(f32, f32)>) -> (Planted, PlantedSpec, Rng) {
        let spec = PlantedSpec {
            dims: vec![15, 12, 10],
            nnz: 200,
            j: 4,
            r_core: 3,
            noise,
            clamp,
        };
        let mut rng = Rng::new(11);
        let p = planted_tucker(&mut rng, &spec);
        (p, spec, rng)
    }

    #[test]
    fn batches_have_requested_shape_and_track_totals() {
        let (p, spec, mut rng) = setup(0.1, None);
        let mut sim = ArrivalSim::from_planted(&p, &spec);
        let a = sim.next_batch(&mut rng, 40);
        let b = sim.next_batch(&mut rng, 25);
        assert_eq!(a.nnz(), 40);
        assert_eq!(b.nnz(), 25);
        assert_eq!(a.dims(), p.tensor.dims());
        assert_eq!(sim.generated(), 65);
        assert!(a.values().iter().chain(b.values()).all(|v| v.is_finite()));
    }

    #[test]
    fn noiseless_arrivals_match_truth() {
        let (p, spec, mut rng) = setup(0.0, None);
        let mut sim = ArrivalSim::from_planted(&p, &spec);
        let batch = sim.next_batch(&mut rng, 50);
        for k in 0..batch.nnz() {
            let want = predict_planted(&p.truth_factors, &p.truth_core, batch.index(k));
            assert!((batch.value(k) - want).abs() < 1e-6);
        }
    }

    #[test]
    fn clamped_arrivals_stay_in_range() {
        let (p, spec, mut rng) = setup(2.0, Some((1.0, 5.0)));
        let mut sim = ArrivalSim::from_planted(&p, &spec);
        let batch = sim.next_batch(&mut rng, 100);
        assert!(batch.values().iter().all(|v| (1.0..=5.0).contains(v)));
    }

    #[test]
    fn zipf_cdf_is_normalized_monotone_and_invertible_at_the_edges() {
        let cdf = zipf_cdf(10, 1.0);
        assert_eq!(cdf.len(), 10);
        assert!(cdf.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(cdf[9], 1.0);
        // Rank 0 carries mass 1/H_10 ~= 0.3414 under exponent 1.
        assert!((cdf[0] - 0.3414).abs() < 1e-3);
        assert_eq!(sample_cdf(&cdf, 0.0), 0);
        assert_eq!(sample_cdf(&cdf, 1.0), 9);
        assert_eq!(sample_cdf(&cdf, cdf[0] + 1e-9), 1);
    }

    #[test]
    fn zipf_arrivals_skew_toward_low_ids() {
        let (p, spec, mut rng) = setup(0.1, None);
        let mut sim = ArrivalSim::from_planted(&p, &spec)
            .with_arrival_model(ArrivalModel::Zipf { exponent: 1.2 });
        assert_eq!(sim.arrival_model(), ArrivalModel::Zipf { exponent: 1.2 });
        let batch = sim.next_batch(&mut rng, 2000);
        // Under Zipf(1.2) on 15 ids, ranks 0..4 carry ~70% of the mass;
        // uniform would give them 4/15 ~= 27%. Split the difference for a
        // comfortably non-flaky bound, and sanity-check the full range.
        let low = (0..batch.nnz()).filter(|&k| batch.index(k)[0] < 4).count();
        assert!(
            low as f64 > 0.5 * batch.nnz() as f64,
            "expected low-id dominance, got {low}/{}",
            batch.nnz()
        );
        assert!((0..batch.nnz()).all(|k| (batch.index(k)[0] as usize) < spec.dims[0]));
    }

    #[test]
    fn zipf_batches_are_deterministic_per_seed() {
        let (p, spec, _) = setup(0.1, None);
        let model = ArrivalModel::Zipf { exponent: 1.1 };
        let mut sim_a = ArrivalSim::from_planted(&p, &spec).with_arrival_model(model);
        let mut sim_b = ArrivalSim::from_planted(&p, &spec).with_arrival_model(model);
        let (mut ra, mut rb) = (Rng::new(99), Rng::new(99));
        let a = sim_a.next_batch(&mut ra, 64);
        let b = sim_b.next_batch(&mut rb, 64);
        for k in 0..a.nnz() {
            assert_eq!(a.index(k), b.index(k));
        }
        assert_eq!(
            a.values().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.values().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn appending_batches_grows_the_base_tensor() {
        let (p, spec, mut rng) = setup(0.1, None);
        let mut sim = ArrivalSim::from_planted(&p, &spec);
        let mut train = p.tensor;
        let rev0 = train.revision();
        let batch = sim.next_batch(&mut rng, 30);
        train.append_tensor(&batch).unwrap();
        assert_eq!(train.nnz(), 230);
        assert_ne!(train.revision(), rev0);
    }
}
