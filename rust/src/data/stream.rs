//! Streaming COO ingest simulation: arrival batches drawn from the same
//! planted ground truth as the base tensor.
//!
//! Real recommender logs grow between retraining runs; the serving
//! session (ISSUE 9) models that as *arrival batches* appended to the
//! training [`SparseTensor`](crate::tensor::SparseTensor) between
//! epochs, at the session boundary. [`ArrivalSim`] holds a clone of a
//! [`Planted`] generator's ground truth and draws fresh observations
//! from it — same signal, same noise floor — so a warm-start epoch over
//! the grown tensor has a recoverable target and the
//! warm-start-beats-cold claim is measurable rather than assumed.
//!
//! Simplification, on purpose: clamped (ratings-style) arrivals clamp
//! the raw planted signal without the empirical offset/gain recentering
//! [`planted_tucker`](crate::data::synth::planted_tucker) applies to the
//! base tensor — the recentering constants are private to the one-shot
//! generator, and a mild distribution shift between the base data and
//! arrivals is itself realistic. Unclamped arrivals are drawn from the
//! identical distribution as the base tensor.

use crate::data::synth::{predict_planted, Planted, PlantedSpec};
use crate::kruskal::KruskalCore;
use crate::model::factors::FactorMatrices;
use crate::tensor::SparseTensor;
use crate::util::Rng;

/// Draws arrival batches from a planted ground truth.
#[derive(Clone, Debug)]
pub struct ArrivalSim {
    dims: Vec<usize>,
    truth_factors: FactorMatrices,
    truth_core: KruskalCore,
    noise: f32,
    clamp: Option<(f32, f32)>,
    /// Total nonzeros generated so far, across all batches.
    generated: usize,
}

impl ArrivalSim {
    /// Build a simulator over `planted`'s ground truth, reusing the
    /// generator spec's noise level and clamp range.
    pub fn from_planted(planted: &Planted, spec: &PlantedSpec) -> Self {
        ArrivalSim {
            dims: spec.dims.clone(),
            truth_factors: planted.truth_factors.clone(),
            truth_core: planted.truth_core.clone(),
            noise: spec.noise,
            clamp: spec.clamp,
            generated: 0,
        }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Total nonzeros produced so far.
    pub fn generated(&self) -> usize {
        self.generated
    }

    /// Draw one arrival batch of `nnz` fresh observations as its own
    /// tensor (append it with
    /// [`SparseTensor::append_tensor`](crate::tensor::SparseTensor::append_tensor)).
    pub fn next_batch(&mut self, rng: &mut Rng, nnz: usize) -> SparseTensor {
        let order = self.dims.len();
        let mut indices = Vec::with_capacity(nnz * order);
        let mut values = Vec::with_capacity(nnz);
        let mut coords = vec![0u32; order];
        for _ in 0..nnz {
            for (n, &d) in self.dims.iter().enumerate() {
                coords[n] = rng.gen_range(d) as u32;
            }
            let mut x = predict_planted(&self.truth_factors, &self.truth_core, &coords);
            x += self.noise * rng.normal();
            if let Some((lo, hi)) = self.clamp {
                x = x.clamp(lo, hi);
            }
            indices.extend_from_slice(&coords);
            values.push(x);
        }
        self.generated += nnz;
        SparseTensor::new_unchecked(self.dims.clone(), indices, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::planted_tucker;

    fn setup(noise: f32, clamp: Option<(f32, f32)>) -> (Planted, PlantedSpec, Rng) {
        let spec = PlantedSpec {
            dims: vec![15, 12, 10],
            nnz: 200,
            j: 4,
            r_core: 3,
            noise,
            clamp,
        };
        let mut rng = Rng::new(11);
        let p = planted_tucker(&mut rng, &spec);
        (p, spec, rng)
    }

    #[test]
    fn batches_have_requested_shape_and_track_totals() {
        let (p, spec, mut rng) = setup(0.1, None);
        let mut sim = ArrivalSim::from_planted(&p, &spec);
        let a = sim.next_batch(&mut rng, 40);
        let b = sim.next_batch(&mut rng, 25);
        assert_eq!(a.nnz(), 40);
        assert_eq!(b.nnz(), 25);
        assert_eq!(a.dims(), p.tensor.dims());
        assert_eq!(sim.generated(), 65);
        assert!(a.values().iter().chain(b.values()).all(|v| v.is_finite()));
    }

    #[test]
    fn noiseless_arrivals_match_truth() {
        let (p, spec, mut rng) = setup(0.0, None);
        let mut sim = ArrivalSim::from_planted(&p, &spec);
        let batch = sim.next_batch(&mut rng, 50);
        for k in 0..batch.nnz() {
            let want = predict_planted(&p.truth_factors, &p.truth_core, batch.index(k));
            assert!((batch.value(k) - want).abs() < 1e-6);
        }
    }

    #[test]
    fn clamped_arrivals_stay_in_range() {
        let (p, spec, mut rng) = setup(2.0, Some((1.0, 5.0)));
        let mut sim = ArrivalSim::from_planted(&p, &spec);
        let batch = sim.next_batch(&mut rng, 100);
        assert!(batch.values().iter().all(|v| (1.0..=5.0).contains(v)));
    }

    #[test]
    fn appending_batches_grows_the_base_tensor() {
        let (p, spec, mut rng) = setup(0.1, None);
        let mut sim = ArrivalSim::from_planted(&p, &spec);
        let mut train = p.tensor;
        let rev0 = train.revision();
        let batch = sim.next_batch(&mut rng, 30);
        train.append_tensor(&batch).unwrap();
        assert_eq!(train.nnz(), 230);
        assert_ne!(train.revision(), rev0);
    }
}
