//! Dataset substrate: synthetic HOHDST generators, FROSTT-style `.tns`
//! text I/O, train/test splitting, and the named dataset registry used by
//! the experiment drivers.
//!
//! The paper evaluates on Netflix, Yahoo!Music and Amazon Reviews, none of
//! which are redistributable here; the registry provides *shaped* synthetic
//! replicas (same order, proportional mode sizes, planted low-rank Tucker
//! structure + noise) — see DESIGN.md §Hardware-Adaptation for why this
//! substitution preserves the evaluation's comparative claims.

pub mod synth;
pub mod stream;
pub mod io;
pub mod split;
pub mod registry;

pub use registry::Dataset;
