//! Train/test splitting of a sparse tensor (the paper's |Ω| / |Γ| split).

use crate::tensor::SparseTensor;
use crate::util::Rng;

/// Split nonzeros uniformly at random: `test_frac` of them become the test
/// set Γ, the rest the training set Ω.
///
/// The test count is clamped so the training side keeps at least one
/// nonzero whenever the input has any: `test_frac` close to 1 used to
/// round `n_test` up to `nnz`, and the resulting empty Ω blew up later in
/// `Sampler::new(0)` deep inside the first epoch instead of here.
pub fn train_test_split(
    t: &SparseTensor,
    test_frac: f64,
    rng: &mut Rng,
) -> (SparseTensor, SparseTensor) {
    assert!((0.0..1.0).contains(&test_frac));
    let nnz = t.nnz();
    let n_test = (((nnz as f64) * test_frac).round() as usize).min(nnz.saturating_sub(1));
    let mut ids: Vec<usize> = (0..nnz).collect();
    rng.shuffle(&mut ids);
    let (test_ids, train_ids) = ids.split_at(n_test);
    let mut train_sorted = train_ids.to_vec();
    let mut test_sorted = test_ids.to_vec();
    // Keep original nonzero order within each side (cache-friendlier).
    train_sorted.sort_unstable();
    test_sorted.sort_unstable();
    (t.gather(&train_sorted), t.gather(&test_sorted))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::propcheck::forall;

    #[test]
    fn split_sizes() {
        let mut rng = Rng::new(7);
        let t = synth::random_uniform(&mut rng, &[20, 20, 20], 1000, 1.0, 5.0);
        let (train, test) = train_test_split(&t, 0.1, &mut rng);
        assert_eq!(test.nnz(), 100);
        assert_eq!(train.nnz(), 900);
        assert_eq!(train.dims(), t.dims());
    }

    #[test]
    fn prop_split_is_partition() {
        forall("train/test split partitions values", 16, |rng| {
            let t = synth::random_uniform(rng, &[15, 15], 200, 0.0, 1.0);
            let frac = 0.05 + 0.4 * rng.uniform() as f64;
            let (train, test) = train_test_split(&t, frac, rng);
            assert_eq!(train.nnz() + test.nnz(), t.nnz());
            // Value multiset is preserved.
            let mut all: Vec<u32> = t.values().iter().map(|v| v.to_bits()).collect();
            let mut got: Vec<u32> = train
                .values()
                .iter()
                .chain(test.values())
                .map(|v| v.to_bits())
                .collect();
            all.sort_unstable();
            got.sort_unstable();
            assert_eq!(all, got);
        });
    }

    #[test]
    fn zero_frac_keeps_everything_in_train() {
        let mut rng = Rng::new(8);
        let t = synth::random_uniform(&mut rng, &[10, 10], 50, 1.0, 2.0);
        let (train, test) = train_test_split(&t, 0.0, &mut rng);
        assert_eq!(train.nnz(), 50);
        assert_eq!(test.nnz(), 0);
    }

    #[test]
    fn high_frac_keeps_at_least_one_train_nonzero() {
        // Regression: test_frac 0.95 on 5 nonzeros rounds to n_test = 5,
        // which used to leave an empty train set that later panicked in
        // Sampler::new(0).
        let mut rng = Rng::new(9);
        let t = synth::random_uniform(&mut rng, &[10, 10], 5, 1.0, 2.0);
        let (train, test) = train_test_split(&t, 0.95, &mut rng);
        assert_eq!(train.nnz(), 1);
        assert_eq!(test.nnz(), 4);
    }

    #[test]
    fn prop_train_is_never_empty() {
        forall("train side never empty for nonempty input", 32, |rng| {
            let nnz = 1 + rng.gen_range(30);
            let t = synth::random_uniform(rng, &[8, 8], nnz, 0.0, 1.0);
            let frac = 0.999f64.min(rng.uniform() as f64);
            let (train, test) = train_test_split(&t, frac, rng);
            assert!(train.nnz() >= 1, "nnz={nnz} frac={frac}");
            assert_eq!(train.nnz() + test.nnz(), nnz);
        });
    }
}
