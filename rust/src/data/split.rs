//! Train/test splitting of a sparse tensor (the paper's |Ω| / |Γ| split).

use crate::tensor::SparseTensor;
use crate::util::Rng;

/// Split nonzeros uniformly at random: `test_frac` of them become the test
/// set Γ, the rest the training set Ω.
pub fn train_test_split(
    t: &SparseTensor,
    test_frac: f64,
    rng: &mut Rng,
) -> (SparseTensor, SparseTensor) {
    assert!((0.0..1.0).contains(&test_frac));
    let nnz = t.nnz();
    let n_test = ((nnz as f64) * test_frac).round() as usize;
    let mut ids: Vec<usize> = (0..nnz).collect();
    rng.shuffle(&mut ids);
    let (test_ids, train_ids) = ids.split_at(n_test);
    let mut train_sorted = train_ids.to_vec();
    let mut test_sorted = test_ids.to_vec();
    // Keep original nonzero order within each side (cache-friendlier).
    train_sorted.sort_unstable();
    test_sorted.sort_unstable();
    (t.gather(&train_sorted), t.gather(&test_sorted))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::propcheck::forall;

    #[test]
    fn split_sizes() {
        let mut rng = Rng::new(7);
        let t = synth::random_uniform(&mut rng, &[20, 20, 20], 1000, 1.0, 5.0);
        let (train, test) = train_test_split(&t, 0.1, &mut rng);
        assert_eq!(test.nnz(), 100);
        assert_eq!(train.nnz(), 900);
        assert_eq!(train.dims(), t.dims());
    }

    #[test]
    fn prop_split_is_partition() {
        forall("train/test split partitions values", 16, |rng| {
            let t = synth::random_uniform(rng, &[15, 15], 200, 0.0, 1.0);
            let frac = 0.05 + 0.4 * rng.uniform() as f64;
            let (train, test) = train_test_split(&t, frac, rng);
            assert_eq!(train.nnz() + test.nnz(), t.nnz());
            // Value multiset is preserved.
            let mut all: Vec<u32> = t.values().iter().map(|v| v.to_bits()).collect();
            let mut got: Vec<u32> = train
                .values()
                .iter()
                .chain(test.values())
                .map(|v| v.to_bits())
                .collect();
            all.sort_unstable();
            got.sort_unstable();
            assert_eq!(all, got);
        });
    }

    #[test]
    fn zero_frac_keeps_everything_in_train() {
        let mut rng = Rng::new(8);
        let t = synth::random_uniform(&mut rng, &[10, 10], 50, 1.0, 2.0);
        let (train, test) = train_test_split(&t, 0.0, &mut rng);
        assert_eq!(train.nnz(), 50);
        assert_eq!(test.nnz(), 0);
    }
}
