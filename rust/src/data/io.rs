//! FROSTT-style `.tns` text I/O.
//!
//! Format: one nonzero per line, `i_1 i_2 ... i_N value`, 1-based indices,
//! `#` comments allowed — the format of frostt.io (the paper's Amazon
//! Reviews source). Mode sizes are inferred as the max index per mode
//! unless explicitly given.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::util::error::{bail, Context, Result};

use crate::tensor::SparseTensor;

/// Load a `.tns` file. `dims`: pass `Some` to validate/fix mode sizes,
/// `None` to infer them from the data.
pub fn load_tns(path: &Path, dims: Option<Vec<usize>>) -> Result<SparseTensor> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let reader = std::io::BufReader::new(file);
    let mut order: Option<usize> = None;
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let mut max_ix: Vec<u32> = Vec::new();

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let fields: Vec<&str> = parts.by_ref().collect();
        if fields.len() < 2 {
            bail!("{path:?}:{}: expected at least 2 fields", lineno + 1);
        }
        let n = fields.len() - 1;
        match order {
            None => {
                order = Some(n);
                max_ix = vec![0; n];
            }
            Some(o) if o != n => {
                bail!("{path:?}:{}: inconsistent order {n} vs {o}", lineno + 1)
            }
            _ => {}
        }
        for (k, f) in fields[..n].iter().enumerate() {
            let ix: u64 = f
                .parse()
                .with_context(|| format!("{path:?}:{}: bad index {f:?}", lineno + 1))?;
            if ix == 0 {
                bail!("{path:?}:{}: .tns indices are 1-based, got 0", lineno + 1);
            }
            let zero_based = (ix - 1) as u32;
            max_ix[k] = max_ix[k].max(zero_based);
            indices.push(zero_based);
        }
        let v: f32 = fields[n]
            .parse()
            .with_context(|| format!("{path:?}:{}: bad value", lineno + 1))?;
        values.push(v);
    }

    let order = order.context("empty .tns file")?;
    let dims = match dims {
        Some(d) => {
            if d.len() != order {
                bail!("given dims order {} != data order {}", d.len(), order);
            }
            d
        }
        None => max_ix.iter().map(|&m| m as usize + 1).collect(),
    };
    SparseTensor::new(dims, indices, values)
}

/// Write a tensor as `.tns` (1-based indices).
pub fn save_tns(t: &SparseTensor, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# order={} dims={:?} nnz={}", t.order(), t.dims(), t.nnz())?;
    for (ix, v) in t.iter() {
        for &i in ix {
            write!(w, "{} ", i + 1)?;
        }
        writeln!(w, "{v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(5);
        let t = synth::random_uniform(&mut rng, &[8, 9, 10], 100, 1.0, 5.0);
        let dir = std::env::temp_dir().join("fasttucker_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.tns");
        save_tns(&t, &path).unwrap();
        let loaded = load_tns(&path, Some(vec![8, 9, 10])).unwrap();
        assert_eq!(loaded.nnz(), t.nnz());
        for k in 0..t.nnz() {
            assert_eq!(loaded.index(k), t.index(k));
            assert!((loaded.value(k) - t.value(k)).abs() < 1e-4);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parses_comments_and_infers_dims() {
        let dir = std::env::temp_dir().join("fasttucker_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("comments.tns");
        std::fs::write(&path, "# hello\n1 1 1 2.5\n3 2 4 1.0\n\n").unwrap();
        let t = load_tns(&path, None).unwrap();
        assert_eq!(t.dims(), &[3, 2, 4]);
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.index(1), &[2, 1, 3]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_zero_index() {
        let dir = std::env::temp_dir().join("fasttucker_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("zero.tns");
        std::fs::write(&path, "0 1 1.0\n").unwrap();
        assert!(load_tns(&path, None).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_mixed_order() {
        let dir = std::env::temp_dir().join("fasttucker_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mixed.tns");
        std::fs::write(&path, "1 1 1.0\n1 1 1 1.0\n").unwrap();
        assert!(load_tns(&path, None).is_err());
        std::fs::remove_file(&path).ok();
    }
}
