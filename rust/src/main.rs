//! `fasttucker` — the launcher.
//!
//! ```text
//! fasttucker train  [--config exp.toml] [--dataset NAME] [--algo A]
//!                   [--engine native|parallel|pjrt] [--j N] [--r-core N]
//!                   [--epochs N] [--workers M] [--seed S] [--scale F]
//!                   [--batch auto|N] [--exactness exact|relaxed]
//!                   [--lanes auto|4|8] [--simd auto|scalar|v128|v256]
//!                   [--wide-accum] [--split N] [--threads auto|N]
//!                   [--devices auto|D] [--transport auto|direct|channel]
//!                   [--prefetch auto|off|async] [--staleness N]
//!                   [--checkpoint OUT.ftck]
//! fasttucker serve  [train flags] [--serve-batches N] [--serve-batch-nnz N]
//!                   [--warm-epochs N] [--queries N] [--candidates N]
//!                   [--topk K] [--cache-capacity N]
//! fasttucker eval   MODEL.ftck --dataset NAME [--seed S]
//! fasttucker gen-data --dataset NAME --out FILE.tns [--scale F] [--seed S]
//! fasttucker partition-plan --workers M --order N
//! fasttucker info   [--artifacts DIR]
//! fasttucker datasets
//! ```

use fasttucker::util::error::{anyhow, bail, Context, Result};

use fasttucker::cli::Args;
use fasttucker::config::{AlgoKind, EngineKind, TrainConfig};
use fasttucker::coordinator::{Session, Trainer};
use fasttucker::data::stream::ArrivalSim;
use fasttucker::data::synth::planted_tucker;
use fasttucker::data::{split::train_test_split, Dataset};
use fasttucker::parallel::LatinSchedule;
use fasttucker::serve::Query;
use fasttucker::util::Rng;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "gen-data" => cmd_gen_data(&args),
        "partition-plan" => cmd_partition_plan(&args),
        "info" => cmd_info(&args),
        "datasets" => cmd_datasets(),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand {other:?}; see `fasttucker help`")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
fasttucker — compact stochastic sparse Tucker decomposition (cuFastTucker reproduction)

USAGE:
  fasttucker train  [--config exp.toml] [--dataset NAME] [--algo ALGO]
                    [--engine native|parallel|pjrt] [--j N] [--r-core N]
                    [--epochs N] [--workers M] [--seed S] [--scale F]
                    [--sample-frac F] [--no-core] [--checkpoint OUT.ftck]
                    [--batch auto|N] [--exactness exact|relaxed]
                    [--lanes auto|4|8] [--simd auto|scalar|v128|v256]
                    [--wide-accum] [--split N] [--threads auto|N]
                    [--devices auto|D] [--transport auto|direct|channel]
                    [--prefetch auto|off|async] [--staleness N]
                    [--eval-every N] [--eval-threads N]
  fasttucker serve  [train flags] [--serve-batches N] [--serve-batch-nnz N]
                    [--warm-epochs N] [--queries N] [--candidates N]
                    [--topk K] [--cache-capacity N]
                    (train, then loop: serve top-k / append arrivals /
                     warm-start retrain — planted datasets only)
  fasttucker eval   MODEL.ftck --dataset NAME [--seed S] [--scale F]
  fasttucker gen-data --dataset NAME --out FILE.tns [--scale F] [--seed S]
  fasttucker partition-plan --workers M --order N
  fasttucker info   [--artifacts DIR]
  fasttucker datasets

ALGO: fasttucker | cutucker | sgd_tucker | ptucker | vest
";

fn apply_overrides(cfg: &mut TrainConfig, args: &Args) -> Result<()> {
    if let Some(v) = args.get("dataset") {
        cfg.dataset = v.to_string();
    }
    if let Some(v) = args.get("algo") {
        cfg.algo = AlgoKind::parse(v)?;
    }
    if let Some(v) = args.get("engine") {
        cfg.engine = EngineKind::parse(v)?;
    }
    if let Some(v) = args.get_usize("j")? {
        cfg.j = v;
    }
    if let Some(v) = args.get_usize("r-core")? {
        cfg.r_core = v;
    }
    if let Some(v) = args.get_usize("epochs")? {
        cfg.epochs = v;
    }
    if let Some(v) = args.get_usize("workers")? {
        cfg.workers = v;
    }
    if let Some(v) = args.get_usize("seed")? {
        cfg.seed = v as u64;
    }
    if let Some(v) = args.get_f64("scale")? {
        cfg.scale = v;
    }
    if let Some(v) = args.get_f64("sample-frac")? {
        cfg.hyper.sample_frac = v;
    }
    if let Some(v) = args.get("batch") {
        cfg.batch = if v == "auto" {
            fasttucker::kernel::BatchSizing::Auto
        } else {
            fasttucker::kernel::BatchSizing::Fixed(
                v.parse().map_err(|_| anyhow!("--batch expects \"auto\" or an integer"))?,
            )
        };
    }
    if let Some(v) = args.get("exactness") {
        cfg.exactness = match v {
            "exact" => fasttucker::kernel::Exactness::Exact,
            "relaxed" | "hogwild" => fasttucker::kernel::Exactness::Relaxed,
            other => bail!("unknown exactness {other:?} (expected exact|relaxed)"),
        };
    }
    if let Some(v) = args.get("lanes") {
        cfg.lanes = fasttucker::kernel::Lanes::parse(v)
            .ok_or_else(|| anyhow!("--lanes expects auto|4|8, got {v:?}"))?;
    }
    if let Some(v) = args.get("simd") {
        cfg.simd = fasttucker::kernel::SimdLevel::parse(v)
            .ok_or_else(|| anyhow!("--simd expects auto|scalar|v128|v256, got {v:?}"))?;
    }
    if args.has_flag("wide-accum") {
        cfg.wide_accum = true;
    }
    if let Some(v) = args.get_usize("split")? {
        cfg.split = v;
    }
    if let Some(v) = args.get("threads") {
        cfg.threads = fasttucker::kernel::ThreadCount::parse(v)
            .ok_or_else(|| anyhow!("--threads expects auto or an integer >= 1, got {v:?}"))?;
    }
    if let Some(v) = args.get("devices") {
        cfg.devices = fasttucker::parallel::DeviceCount::parse(v)
            .ok_or_else(|| anyhow!("--devices expects auto or an integer >= 1, got {v:?}"))?;
    }
    if let Some(v) = args.get("transport") {
        cfg.transport = fasttucker::parallel::TransportKind::parse(v)
            .ok_or_else(|| anyhow!("--transport expects auto|direct|channel, got {v:?}"))?;
    }
    if let Some(v) = args.get("prefetch") {
        cfg.prefetch = fasttucker::parallel::PrefetchMode::parse(v)
            .ok_or_else(|| anyhow!("--prefetch expects auto|off|async, got {v:?}"))?;
    }
    if let Some(v) = args.get_usize("staleness")? {
        cfg.staleness = v;
    }
    if let Some(v) = args.get_usize("eval-every")? {
        cfg.eval_every = v;
    }
    if let Some(v) = args.get_usize("eval-threads")? {
        cfg.eval_threads = v;
    }
    if args.has_flag("no-core") {
        cfg.hyper.update_core = false;
    }
    if let Some(v) = args.get("checkpoint") {
        cfg.checkpoint = Some(v.to_string());
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = v.to_string();
    }
    cfg.validate()
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::from_file(std::path::Path::new(path))?,
        None => TrainConfig::default(),
    };
    apply_overrides(&mut cfg, args)?;

    let mut rng = Rng::new(cfg.seed);
    let dataset = Dataset::by_name(&cfg.dataset, cfg.scale)?;
    let tensor = dataset.build(&mut rng)?;
    println!(
        "dataset={} order={} dims={:?} nnz={} density={:.2e}",
        cfg.dataset,
        tensor.order(),
        tensor.dims(),
        tensor.nnz(),
        tensor.density()
    );
    let (train, test) = train_test_split(&tensor, cfg.test_frac, &mut rng);
    println!("train nnz={} test nnz={}", train.nnz(), test.nnz());

    let dims = tensor.dims().to_vec();
    let (mut trainer, mut model) =
        Trainer::from_config_for(&cfg, &dims, Some(train.nnz()), &mut rng)?;
    println!(
        "algo={} engine={} J={} R_core={} params={}",
        cfg.algo.name(),
        trainer.engine.name(),
        cfg.j,
        cfg.r_core,
        model.param_count()
    );
    let report = trainer.train(&mut model, &train, &test, &mut rng)?;
    println!("epoch\trmse\tmae\ttrain_secs");
    for rec in &report.history {
        println!(
            "{}\t{:.6}\t{:.6}\t{:.3}",
            rec.epoch, rec.rmse, rec.mae, rec.train_secs
        );
    }
    println!(
        "final: rmse={:.6} mae={:.6} total_train_secs={:.3}",
        report.final_rmse(),
        report.final_mae(),
        report.total_train_secs()
    );
    if let Some(path) = &cfg.checkpoint {
        fasttucker::model::checkpoint::save(&model, std::path::Path::new(path))?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

/// The streaming serving loop: train, then alternate top-k serving,
/// arrival-batch appends, and warm-start retraining in one long-lived
/// [`Session`]. Planted datasets only — the arrival stream draws from
/// the same ground truth the base tensor was generated from.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::from_file(std::path::Path::new(path))?,
        None => TrainConfig::default(),
    };
    apply_overrides(&mut cfg, args)?;
    let serve_batches = args.get_usize("serve-batches")?.unwrap_or(2);
    let batch_nnz = args.get_usize("serve-batch-nnz")?.unwrap_or(500);
    let warm_epochs = args.get_usize("warm-epochs")?.unwrap_or(2);
    let queries = args.get_usize("queries")?.unwrap_or(64);
    let candidates = args.get_usize("candidates")?.unwrap_or(100);
    let topk = args.get_usize("topk")?.unwrap_or(10);
    let cache_capacity = args.get_usize("cache-capacity")?.unwrap_or(256);

    let mut rng = Rng::new(cfg.seed);
    let spec = match Dataset::by_name(&cfg.dataset, cfg.scale)? {
        Dataset::Planted(spec) => spec,
        _ => bail!(
            "serve needs a planted dataset (its ground truth drives the arrival \
             stream); pick tiny/small/netflix-like/yahoo-like/amazon-like"
        ),
    };
    let planted = planted_tucker(&mut rng, &spec);
    let (train, test) = train_test_split(&planted.tensor, cfg.test_frac, &mut rng);
    println!(
        "dataset={} dims={:?} train nnz={} test nnz={}",
        cfg.dataset,
        spec.dims,
        train.nnz(),
        test.nnz()
    );
    let mut sim = ArrivalSim::from_planted(&planted, &spec);
    let mut session = Session::new(&cfg, train, test, cache_capacity, &mut rng)?;
    println!(
        "engine={} algo={} J={} R_core={} cache_capacity={cache_capacity}",
        session.engine_name(),
        cfg.algo.name(),
        cfg.j,
        cfg.r_core
    );

    let report = session.train_epochs(cfg.epochs)?;
    println!(
        "initial train: {} epochs, rmse={:.6}, {:.3}s",
        cfg.epochs,
        report.final_rmse(),
        report.total_train_secs()
    );

    let mut qrng = rng.fork();
    serve_round(&mut session, &mut qrng, &spec.dims, queries, candidates, topk, 0);
    for b in 0..serve_batches {
        let batch = sim.next_batch(&mut rng, batch_nnz);
        session.append(&batch)?;
        let report = session.train_epochs(warm_epochs)?;
        println!(
            "append #{}: +{} nnz (total {}), warm-start {} epochs -> rmse={:.6}",
            b + 1,
            batch_nnz,
            session.train_tensor().nnz(),
            warm_epochs,
            report.final_rmse()
        );
        serve_round(&mut session, &mut qrng, &spec.dims, queries, candidates, topk, b + 1);
    }

    let c = session.cache_counters();
    println!(
        "cache: hits={} misses={} evictions={} invalidations={} hit_rate={:.3}",
        c.hits, c.misses, c.evictions, c.invalidations, c.hit_rate()
    );
    if let Some(r) = session.engine_rebuilds() {
        println!(
            "engine rebuilds: partition={} planner={}",
            r.partition, r.planner
        );
    }
    Ok(())
}

/// One serving round: `queries` top-k requests over random candidate
/// panels, drawing users from a small pool so the hot-row cache sees
/// repeats. Prints predictions/sec for the round.
fn serve_round(
    session: &mut Session,
    rng: &mut Rng,
    dims: &[usize],
    queries: usize,
    candidates: usize,
    k: usize,
    round: usize,
) {
    let mode = if dims.len() > 1 { 1 } else { 0 };
    let pool = (queries / 4).max(1);
    let users: Vec<Vec<u32>> = (0..pool)
        .map(|_| dims.iter().map(|&d| rng.gen_range(d) as u32).collect())
        .collect();
    let start = std::time::Instant::now();
    let mut checksum = 0u64;
    for i in 0..queries {
        let cands: Vec<u32> = (0..candidates)
            .map(|_| rng.gen_range(dims[mode]) as u32)
            .collect();
        let q = Query {
            coords: users[i % pool].clone(),
            candidate_mode: mode,
            candidates: cands,
        };
        let top = session.top_k(&q, k);
        // Fold the results so the serving work cannot be optimized away.
        for s in &top {
            checksum = checksum.wrapping_add(u64::from(s.item)) ^ u64::from(s.score.to_bits());
        }
    }
    let secs = start.elapsed().as_secs_f64().max(1e-9);
    let preds = (queries * candidates) as f64;
    println!(
        "serve round {round}: {queries} queries x {candidates} candidates -> \
         {:.0} predictions/sec (checksum {checksum:#x})",
        preds / secs
    );
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model_path = args
        .positional()
        .first()
        .context("usage: fasttucker eval MODEL.ftck --dataset NAME")?;
    let dataset_name = args.get("dataset").context("--dataset required")?;
    let scale = args.get_f64("scale")?.unwrap_or(1.0);
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;

    let model = fasttucker::model::checkpoint::load(std::path::Path::new(model_path))?;
    let mut rng = Rng::new(seed);
    let tensor = Dataset::by_name(dataset_name, scale)?.build(&mut rng)?;
    if tensor.order() != model.order() {
        bail!(
            "model order {} != dataset order {}",
            model.order(),
            tensor.order()
        );
    }
    let (rmse, mae) = fasttucker::coordinator::eval::rmse_mae_parallel(&model, &tensor, 4);
    println!("rmse={rmse:.6} mae={mae:.6} over {} nonzeros", tensor.nnz());
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let name = args.get("dataset").context("--dataset required")?;
    let out = args.get("out").context("--out required")?;
    let scale = args.get_f64("scale")?.unwrap_or(1.0);
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let mut rng = Rng::new(seed);
    let tensor = Dataset::by_name(name, scale)?.build(&mut rng)?;
    fasttucker::data::io::save_tns(&tensor, std::path::Path::new(out))?;
    println!(
        "wrote {out}: order={} dims={:?} nnz={}",
        tensor.order(),
        tensor.dims(),
        tensor.nnz()
    );
    Ok(())
}

fn cmd_partition_plan(args: &Args) -> Result<()> {
    let m = args.get_usize("workers")?.unwrap_or(2);
    let order = args.get_usize("order")?.unwrap_or(3);
    let s = LatinSchedule::try_new(m, order)?;
    println!("workers={m} order={order} rounds={}", s.rounds());
    for round in 0..s.rounds() {
        let assigns = s.round_assignments(round);
        let desc: Vec<String> = assigns
            .iter()
            .enumerate()
            .map(|(g, a)| format!("w{g}->{a:?}"))
            .collect();
        println!("round {round}: {}", desc.join("  "));
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    println!("fasttucker {} (offline build)", env!("CARGO_PKG_VERSION"));
    let path = std::path::Path::new(dir);
    match fasttucker::runtime::Manifest::load(path) {
        Ok(m) => {
            println!("artifacts in {dir}:");
            for e in m.entries() {
                println!(
                    "  {} J={} R={} B={} outputs={} ({})",
                    e.name,
                    e.j,
                    e.r_core,
                    e.batch,
                    e.n_outputs,
                    e.file.display()
                );
            }
        }
        Err(e) => println!("no artifacts loaded from {dir}: {e}"),
    }
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("registered datasets:");
    for name in Dataset::names() {
        println!("  {name}");
    }
    Ok(())
}
