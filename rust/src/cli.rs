//! Hand-rolled CLI argument parsing (offline build: no clap).
//!
//! Grammar: `fasttucker <subcommand> [--key value]... [--flag]...`.

use std::collections::HashMap;

use crate::util::error::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut out = Args { subcommand, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| anyhow!("--{key}: {e}")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| anyhow!("--{key}: {e}")))
            .transpose()
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--dataset", "tiny", "--epochs", "5", "--verbose"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("dataset"), Some("tiny"));
        assert_eq!(a.get_usize("epochs").unwrap(), Some(5));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get("nope"), None);
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["train", "--j=16", "--scale=0.5"]);
        assert_eq!(a.get_usize("j").unwrap(), Some(16));
        assert_eq!(a.get_f64("scale").unwrap(), Some(0.5));
    }

    #[test]
    fn positional_args() {
        let a = parse(&["eval", "model.ftck", "--dataset", "tiny"]);
        assert_eq!(a.positional(), &["model.ftck".to_string()]);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["train", "--epochs", "abc"]);
        assert!(a.get_usize("epochs").is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["train", "--quiet"]);
        assert!(a.has_flag("quiet"));
    }
}
