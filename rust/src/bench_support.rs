//! Bench harness (offline build: no criterion). Each `rust/benches/*.rs`
//! binary uses [`bench`] / [`Table`] to time closures with warmup and
//! repetition and print paper-style tables to stdout.

use std::time::Instant;

use crate::metrics::Stats;

/// Result of one timed case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn per_iter_display(&self) -> String {
        format!("{:.6}s ± {:.6}", self.mean_secs, self.std_secs)
    }
}

/// Time `f` for `iters` measured runs after `warmup` unmeasured ones.
/// `f` receives the 0-based run index (warmup runs get indices too, so
/// epoch-dependent schedules keep advancing).
pub fn bench<F: FnMut(usize)>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for i in 0..warmup {
        f(i);
    }
    let mut stats = Stats::new();
    for i in 0..iters {
        let t0 = Instant::now();
        f(warmup + i);
        stats.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        mean_secs: stats.mean(),
        std_secs: stats.std(),
        iters,
    }
}

/// A fixed-width text table printer for the bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Parse `--filter substr` style args for bench binaries (cargo bench
/// passes through extra args after `--`).
pub fn bench_filter() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    // Accept both `--filter x` and a bare positional filter.
    let mut it = args.iter().skip(1).peekable();
    while let Some(a) = it.next() {
        if a == "--filter" {
            return it.next().cloned();
        }
        if a == "--bench" || a.starts_with("--") {
            continue;
        }
        return Some(a.clone());
    }
    None
}

/// `FASTTUCKER_BENCH_SCALE` scales workload sizes (default 1.0); CI can set
/// 0.1 for fast smoke runs. A malformed or non-positive value is a hard
/// error (exit 2), not a silent fall-back to 1.0 — a typo'd scale would
/// otherwise quietly run the full-size workloads (ISSUE 4 regression).
pub fn bench_scale() -> f64 {
    match parse_scale(std::env::var("FASTTUCKER_BENCH_SCALE").ok().as_deref()) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("FASTTUCKER_BENCH_SCALE: {e}");
            std::process::exit(2);
        }
    }
}

/// Pure validation behind [`bench_scale`] (unit-tested; `None` = unset).
pub fn parse_scale(raw: Option<&str>) -> Result<f64, String> {
    let Some(raw) = raw else { return Ok(1.0) };
    let v: f64 = raw
        .trim()
        .parse()
        .map_err(|_| format!("expected a number, got {raw:?}"))?;
    if !v.is_finite() || v <= 0.0 {
        return Err(format!("scale must be finite and > 0, got {v}"));
    }
    Ok(v)
}

/// Bench-regression gate support: parse `BENCH_kernels.json`-format
/// snapshots and compare throughput against a committed baseline
/// (`BENCH_baseline.json`).
///
/// The gated metric is `speedup_vs_scalar` — throughput normalized by the
/// same run's scalar-kernel pass on the same machine — so the committed
/// baseline transfers across CI runners; a >`tolerance` relative drop on
/// any pinned `(workload, path, cap)` fails the gate. Refreshing the
/// baseline is one command (the documented override knob):
///
/// ```text
/// cargo bench --bench bench_kernels -- --quick --json BENCH_baseline.json
/// ```
///
/// and `FASTTUCKER_BENCH_TOLERANCE` (a fraction, default `0.15`)
/// loosens/tightens the gate without touching the baseline.
pub mod regression {
    /// One gated measurement: `(workload, path, cap)` → speedup.
    #[derive(Clone, Debug, PartialEq)]
    pub struct Entry {
        pub workload: String,
        pub path: String,
        /// Group cap of the path (`None` for the scalar baseline row).
        pub cap: Option<usize>,
        pub speedup_vs_scalar: f64,
    }

    impl Entry {
        pub fn key(&self) -> String {
            match self.cap {
                Some(c) => format!("{}/{}@{}", self.workload, self.path, c),
                None => format!("{}/{}", self.workload, self.path),
            }
        }
    }

    /// Extract the gated entries from a `BENCH_kernels.json` snapshot
    /// (the hand-rolled format `bench_kernels --json` emits; no serde in
    /// the offline build, so this is a line-oriented field scanner).
    pub fn parse_entries(json: &str) -> Vec<Entry> {
        fn field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
            let tag = format!("\"{key}\":");
            let start = line.find(&tag)? + tag.len();
            let rest = line[start..].trim_start();
            let end = rest
                .find([',', '}'])
                .unwrap_or(rest.len());
            Some(rest[..end].trim().trim_matches('"'))
        }
        let mut workload = String::new();
        let mut out = Vec::new();
        for line in json.lines() {
            if let Some(name) = field(line, "name") {
                workload = name.to_string();
            }
            if let Some(path) = field(line, "path") {
                let cap = field(line, "cap").and_then(|v| v.parse::<usize>().ok());
                let speedup = field(line, "speedup_vs_scalar")
                    .and_then(|v| v.parse::<f64>().ok());
                if let Some(speedup_vs_scalar) = speedup {
                    out.push(Entry {
                        workload: workload.clone(),
                        path: path.to_string(),
                        cap,
                        speedup_vs_scalar,
                    });
                }
            }
        }
        out
    }

    /// Gate verdict: regressions (fail) and notes (baseline gaps, skipped
    /// keys — reported but not fatal, so a planner-driven cap change
    /// degrades the gate loudly instead of failing spuriously).
    /// `matched` counts baseline entries actually compared: a gate run
    /// with `matched == 0` compared nothing (format drift or a total key
    /// rename) and MUST be treated as a failure by the caller — the
    /// bench's `--check` does.
    #[derive(Clone, Debug, Default)]
    pub struct GateReport {
        pub regressions: Vec<String>,
        pub notes: Vec<String>,
        /// Baseline entries that found a matching current entry.
        pub matched: usize,
    }

    impl GateReport {
        /// No regressions AND at least one entry was actually compared.
        pub fn passed(&self) -> bool {
            self.regressions.is_empty() && self.matched > 0
        }
    }

    /// Compare a current snapshot against the committed baseline:
    /// `current < baseline * (1 - tolerance)` on any shared key is a
    /// regression.
    pub fn check(current: &[Entry], baseline: &[Entry], tolerance: f64) -> GateReport {
        let mut report = GateReport::default();
        for base in baseline {
            let key = base.key();
            match current.iter().find(|e| e.key() == key) {
                Some(cur) => {
                    report.matched += 1;
                    let floor = base.speedup_vs_scalar * (1.0 - tolerance);
                    if cur.speedup_vs_scalar < floor {
                        report.regressions.push(format!(
                            "{key}: speedup {:.3}x < floor {:.3}x (baseline {:.3}x, tolerance {:.0}%)",
                            cur.speedup_vs_scalar,
                            floor,
                            base.speedup_vs_scalar,
                            tolerance * 100.0
                        ));
                    }
                }
                None => report.notes.push(format!(
                    "{key}: in baseline but not in current run (cap/path renamed? refresh the baseline)"
                )),
            }
        }
        for cur in current {
            if !baseline.iter().any(|b| b.key() == cur.key()) {
                report
                    .notes
                    .push(format!("{}: not in baseline (ungated)", cur.key()));
            }
        }
        report
    }

    /// Gate tolerance from `FASTTUCKER_BENCH_TOLERANCE` (default 0.15 =
    /// the 15% throughput-drop bar). A malformed or out-of-range value
    /// is a hard error (exit 2): the old `.ok()` chain silently fell
    /// back to the default — and accepted negative tolerances, which
    /// turn the gate into "any run slower than baseline fails" — so a
    /// typo'd override would misgate without a trace (ISSUE 4
    /// regression).
    pub fn tolerance_from_env() -> f64 {
        match parse_tolerance(std::env::var("FASTTUCKER_BENCH_TOLERANCE").ok().as_deref()) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("FASTTUCKER_BENCH_TOLERANCE: {e}");
                std::process::exit(2);
            }
        }
    }

    /// Pure validation behind [`tolerance_from_env`] (unit-tested;
    /// `None` = unset). A tolerance is a drop fraction: `[0, 1)`.
    pub fn parse_tolerance(raw: Option<&str>) -> Result<f64, String> {
        let Some(raw) = raw else { return Ok(0.15) };
        let v: f64 = raw
            .trim()
            .parse()
            .map_err(|_| format!("expected a fraction in [0, 1), got {raw:?}"))?;
        if !v.is_finite() || !(0.0..1.0).contains(&v) {
            return Err(format!(
                "tolerance must be a drop fraction in [0, 1), got {v}"
            ));
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let r = bench("noop", 2, 5, |_| count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean_secs >= 0.0);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }

    const SNAPSHOT: &str = r#"{
  "bench": "kernels",
  "workloads": [
    {"name": "tall", "dims": [256, 60000, 60000], "nnz": 150000, "mean_fiber_len": 585.9375, "paths": [
      {"path": "scalar", "cap": null, "tile": null, "mean_group_len": 1.0000, "mean_fibers_per_group": 1.0000, "occupancy": 1.0000, "secs_per_pass": 0.5, "msamples_per_sec": 0.3, "speedup_vs_scalar": 1.0000},
      {"path": "tiled", "cap": 256, "tile": 1, "mean_group_len": 200.1, "mean_fibers_per_group": 1.0000, "occupancy": 0.8, "secs_per_pass": 0.3, "msamples_per_sec": 0.5, "speedup_vs_scalar": 1.6000}
    ]},
    {"name": "hollow", "dims": [75000, 30000, 30000], "nnz": 150000, "mean_fiber_len": 1.7, "paths": [
      {"path": "tiled", "cap": 256, "tile": 64, "mean_group_len": 40.0, "mean_fibers_per_group": 24.0, "occupancy": 0.2, "secs_per_pass": 0.4, "msamples_per_sec": 0.4, "speedup_vs_scalar": 1.2000}
    ]}
  ]
}
"#;

    #[test]
    fn regression_parser_extracts_keys_and_speedups() {
        let entries = regression::parse_entries(SNAPSHOT);
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0].key(), "tall/scalar");
        assert_eq!(entries[1].key(), "tall/tiled@256");
        assert_eq!(entries[2].key(), "hollow/tiled@256");
        assert!((entries[1].speedup_vs_scalar - 1.6).abs() < 1e-9);
        assert!((entries[2].speedup_vs_scalar - 1.2).abs() < 1e-9);
        assert_eq!(entries[0].cap, None);
    }

    #[test]
    fn regression_gate_fails_on_drop_and_reports_gaps() {
        let baseline = regression::parse_entries(SNAPSHOT);
        // Identical snapshot: pass.
        assert!(regression::check(&baseline, &baseline, 0.15).passed());

        // 10% drop within a 15% tolerance: pass; 20% drop: fail.
        let mut drop10 = baseline.clone();
        drop10[1].speedup_vs_scalar *= 0.90;
        assert!(regression::check(&drop10, &baseline, 0.15).passed());
        let mut drop20 = baseline.clone();
        drop20[1].speedup_vs_scalar *= 0.80;
        let report = regression::check(&drop20, &baseline, 0.15);
        assert!(!report.passed());
        assert!(report.regressions[0].contains("tall/tiled@256"));

        // A renamed key degrades to a note, not a failure.
        let mut renamed = baseline.clone();
        renamed[2].cap = Some(512);
        let report = regression::check(&renamed, &baseline, 0.15);
        assert!(report.passed());
        assert_eq!(report.matched, 2);
        assert_eq!(report.notes.len(), 2, "missing + ungated: {:?}", report.notes);

        // A current run that shares NO keys with the baseline (format
        // drift, empty parse) compared nothing — that is a failure, not
        // a silent pass.
        let report = regression::check(&[], &baseline, 0.15);
        assert_eq!(report.matched, 0);
        assert!(!report.passed(), "vacuous gate run must not pass");
    }

    #[test]
    fn tolerance_env_values_are_validated_not_defaulted() {
        // ISSUE 4 satellite: malformed/out-of-range overrides must be
        // rejected instead of silently becoming the 0.15 default.
        assert_eq!(regression::parse_tolerance(None), Ok(0.15));
        assert_eq!(regression::parse_tolerance(Some("0.2")), Ok(0.2));
        assert_eq!(regression::parse_tolerance(Some(" 0.05 ")), Ok(0.05));
        assert_eq!(regression::parse_tolerance(Some("0")), Ok(0.0));
        assert!(regression::parse_tolerance(Some("15%")).is_err());
        assert!(regression::parse_tolerance(Some("abc")).is_err());
        assert!(regression::parse_tolerance(Some("")).is_err());
        // Negative tolerances were silently accepted before — they make
        // the floor EXCEED the baseline, failing every honest run.
        assert!(regression::parse_tolerance(Some("-0.1")).is_err());
        assert!(regression::parse_tolerance(Some("1.0")).is_err());
        assert!(regression::parse_tolerance(Some("NaN")).is_err());
        assert!(regression::parse_tolerance(Some("inf")).is_err());
    }

    #[test]
    fn scale_env_values_are_validated_not_defaulted() {
        assert_eq!(parse_scale(None), Ok(1.0));
        assert_eq!(parse_scale(Some("0.1")), Ok(0.1));
        assert_eq!(parse_scale(Some("2")), Ok(2.0));
        assert!(parse_scale(Some("fast")).is_err());
        assert!(parse_scale(Some("0")).is_err());
        assert!(parse_scale(Some("-1")).is_err());
        assert!(parse_scale(Some("inf")).is_err());
        assert!(parse_scale(Some("NaN")).is_err());
    }
}
