//! Bench harness (offline build: no criterion). Each `rust/benches/*.rs`
//! binary uses [`bench`] / [`Table`] to time closures with warmup and
//! repetition and print paper-style tables to stdout.

use std::time::Instant;

use crate::metrics::Stats;

/// Result of one timed case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn per_iter_display(&self) -> String {
        format!("{:.6}s ± {:.6}", self.mean_secs, self.std_secs)
    }
}

/// Time `f` for `iters` measured runs after `warmup` unmeasured ones.
/// `f` receives the 0-based run index (warmup runs get indices too, so
/// epoch-dependent schedules keep advancing).
pub fn bench<F: FnMut(usize)>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for i in 0..warmup {
        f(i);
    }
    let mut stats = Stats::new();
    for i in 0..iters {
        let t0 = Instant::now();
        f(warmup + i);
        stats.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        mean_secs: stats.mean(),
        std_secs: stats.std(),
        iters,
    }
}

/// A fixed-width text table printer for the bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Parse `--filter substr` style args for bench binaries (cargo bench
/// passes through extra args after `--`).
pub fn bench_filter() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    // Accept both `--filter x` and a bare positional filter.
    let mut it = args.iter().skip(1).peekable();
    while let Some(a) = it.next() {
        if a == "--filter" {
            return it.next().cloned();
        }
        if a == "--bench" || a.starts_with("--") {
            continue;
        }
        return Some(a.clone());
    }
    None
}

/// `FASTTUCKER_BENCH_SCALE` scales workload sizes (default 1.0); CI can set
/// 0.1 for fast smoke runs.
pub fn bench_scale() -> f64 {
    std::env::var("FASTTUCKER_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let r = bench("noop", 2, 5, |_| count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean_secs >= 0.0);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }
}
