//! Concurrency-safety analysis layer: the three-level disjointness
//! contract of `parallel/shared.rs`, *audited by construction* instead
//! of tested by example. Three legs:
//!
//! 1. **Disjointness auditor** ([`audit`]) — an independent,
//!    first-principles checker (brute-force conflict graphs + set
//!    algebra, sharing no code with the builders) for all three levels:
//!    color waves ([`audit_coloring`]), Latin rounds ([`audit_latin`]),
//!    and the device grid ([`audit_grid`]). Violations are named
//!    [`Violation`] variants in an [`AuditReport`]. With the
//!    `strict-audit` cargo feature the engines run it on every coloring
//!    and every grid they build and panic on a red report; the
//!    `audit_plan` binary runs it ad hoc on synthetic geometries. A
//!    fourth leg, [`audit_exchange`], replays the channel transport's
//!    event log and proves every delivered panel was applied exactly
//!    once, strictly inside its round's barrier window; under async
//!    prefetch the transfer may pipeline ahead of the window but the
//!    apply may not, and [`audit_exchange_with_staleness`] relaxes
//!    only the latter by the configured bound (`strict-audit` runs the
//!    staleness-aware form on every epoch's log).
//! 2. **Shadow race detector** ([`shadow`]) — `shadow-ledger`-gated
//!    instrumentation in `SharedFactors` records every row access with
//!    full provenance `(epoch, round, worker, wave, thread, mode, row,
//!    kind)`; the post-pass happens-before check mirrors the engine's
//!    barrier structure (exact mode: zero same-wave or same-round
//!    overlap; relaxed mode: a contention histogram instead of a
//!    failure — the first measured view of hogwild contention).
//! 3. **Unsafe-discipline lint** ([`lint`]) — a unit-tested source
//!    scanner that fails `cargo test` when an `unsafe` block lacks a
//!    `SAFETY` comment or a file outside the four allowlisted modules
//!    introduces `unsafe`. CI adds Miri and ThreadSanitizer legs over
//!    the same four modules (`.github/workflows/ci.yml`).
//!
//! The contract itself — why the `unsafe impl Send/Sync` on
//! `SharedFactors` is sound — is documented once, in
//! `parallel/shared.rs`; everything in this module checks that
//! documentation against reality.

pub mod audit;
pub mod lint;
pub mod shadow;

pub use audit::{
    audit_coloring, audit_exchange, audit_exchange_with_staleness, audit_grid, audit_latin,
    audit_schedule_and_grid,
    gather_grid_facts, waves_of, AuditReport, GridFacts, Violation,
};
pub use shadow::{AccessKind, RaceViolation, ShadowLog, ShadowSession};
