//! Independent disjointness auditor for the three-level concurrency
//! contract (see `parallel/shared.rs`).
//!
//! Everything here is deliberately re-derived from first principles —
//! brute-force conflict graphs and plain set algebra over the *inputs*
//! (tensor indices, wave lists, chunk coordinates, worker ranges) — and
//! shares **no code** with the builders under audit
//! ([`crate::kernel::plan::color_subgroups`], [`crate::parallel::LatinSchedule`],
//! [`crate::parallel::DeviceGrid`]). A bug in a builder therefore cannot
//! hide inside the checker that is supposed to catch it. The only
//! geometry the auditor re-states is the ceil-split chunk rule
//! (`chunk = ceil(dim / m)`), written out locally in [`chunk_rows`].
//!
//! The audited contract, level by level:
//!
//! - **Level 2 (color waves)** — [`audit_coloring`]: the waves are a
//!   partition of the plan's sub-groups; same-wave sub-groups share no
//!   factor row in any mode; for two sub-groups that *do* share a row,
//!   their wave order preserves their plan order.
//! - **Level 1 (Latin rounds)** — [`audit_latin`]: within a round, the
//!   workers' chunk assignments are row-disjoint in every mode, every
//!   assignment is well-formed, and a full cycle visits each block
//!   exactly once.
//! - **Level 0 (device grid)** — [`audit_grid`] over [`GridFacts`]: the
//!   per-device worker ranges partition the workers; the owned row
//!   ranges tile every mode exactly; every nonzero lands on exactly one
//!   device (the owner of its mode-0 row); and each round's boundary
//!   set is the exact complement of the home set within the touched
//!   chunks.
//! - **In-flight exchange** — [`audit_exchange`] over the transport's
//!   [`ExchangeEvent`](crate::parallel::ExchangeEvent) log: every panel
//!   apply lands strictly inside its round's barrier window (after
//!   `BarrierStart`, before `ComputeStart`), each sequence number is
//!   applied at most once, nothing is applied that was never delivered,
//!   and nothing delivered is left unapplied when the workers resume.
//!   A `ComputeStart` with no preceding `BarrierStart` is *not* a
//!   violation — panel-free rounds legitimately skip the window.
//!
//! Violations come back as named [`Violation`] variants inside an
//! [`AuditReport`]; with the `strict-audit` cargo feature the engines
//! run these audits on every coloring/grid they build (and on every
//! epoch's exchange log) and panic on the first red report.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use crate::kernel::{BatchPlan, SubGroupColoring};
use crate::parallel::{DeviceGrid, ExchangeEvent, LatinSchedule};
use crate::tensor::SparseTensor;

/// One named contract violation. Each variant carries enough provenance
/// to locate the offending object without re-running the audit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// A sub-group of the plan appears in no wave.
    WavePartitionGap { group: usize },
    /// A sub-group appears in more than one wave (or twice in one).
    WavePartitionDuplicate { group: usize },
    /// A wave names a group id outside the plan's `0..n_groups` range.
    WaveUnknownGroup { wave: usize, group: usize },
    /// Two sub-groups in the same wave touch the same factor row.
    WaveRowOverlap { wave: usize, group_a: usize, group_b: usize, mode: usize, row: usize },
    /// Two conflicting sub-groups run in waves that invert their plan
    /// order (`group_a < group_b` but `wave_a > wave_b`).
    WaveOrderInversion {
        group_a: usize,
        group_b: usize,
        wave_a: usize,
        wave_b: usize,
        mode: usize,
        row: usize,
    },
    /// A Latin assignment has the wrong arity or an out-of-range chunk.
    LatinMalformedAssignment { round: usize, worker: usize },
    /// Two workers of one round touch the same row of the same mode.
    LatinRowOverlap { round: usize, mode: usize, worker_a: usize, worker_b: usize, row: usize },
    /// A full Latin cycle visits the same block twice.
    LatinBlockRevisited { round: usize, worker: usize },
    /// A full Latin cycle never visits some block.
    LatinCoverageGap { block: Vec<usize> },
    /// A worker belongs to no device range.
    DeviceWorkerGap { worker: usize },
    /// A worker belongs to two device ranges.
    DeviceWorkerOverlap { worker: usize, device_a: usize, device_b: usize },
    /// A factor row of some mode is homed on no device.
    OwnershipGap { mode: usize, row: usize },
    /// A factor row of some mode is homed on two devices.
    OwnershipOverlap { mode: usize, row: usize, device_a: usize, device_b: usize },
    /// A device's owned range differs from the union of its workers'
    /// chunk ranges.
    OwnershipMismatch { device: usize, mode: usize },
    /// A nonzero is assigned to a device other than the owner of its
    /// mode-0 row.
    NnzDeviceMismatch { nnz: usize, assigned: usize, expected: usize },
    /// A round's boundary set misses a remote chunk the device touches.
    BoundaryMissing { device: usize, round: usize, mode: usize, chunk: usize },
    /// A round's boundary set lists a chunk the device homes (or never
    /// touches).
    BoundarySpurious { device: usize, round: usize, mode: usize, chunk: usize },
    /// A panel was applied before its round's exchange window opened
    /// (no `BarrierStart` for that `(epoch, round)` yet) — the write
    /// could race workers still inside the previous round.
    ExchangeApplyBeforeBarrier { epoch: usize, round: usize, seq: u64 },
    /// A panel was applied after `ComputeStart` released the workers —
    /// the write could race workers already inside the round.
    ExchangeApplyAfterCompute { epoch: usize, round: usize, seq: u64 },
    /// The same sequence number was applied twice (dedup failed; the
    /// second write would double-apply a core-gradient panel).
    ExchangeDuplicateApply { seq: u64 },
    /// A sequence number was applied that no delivery produced.
    ExchangePhantomApply { seq: u64 },
    /// A delivered panel was never applied before its round's
    /// `ComputeStart` (or before the log ended) — its destination rows
    /// silently kept stale values.
    ExchangeUnappliedDelivery { epoch: usize, round: usize, seq: u64 },
    /// A panel was applied later than the relaxed staleness bound
    /// allows: `late_by` exchange windows at-or-after the panel's own
    /// round had already closed, exceeding the audited `max_staleness`
    /// (the bounded-staleness contract of async prefetch).
    ExchangeStalenessExceeded { epoch: usize, round: usize, seq: u64, late_by: usize },
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::WavePartitionGap { group } => {
                write!(f, "wave partition gap: sub-group {group} is in no wave")
            }
            Violation::WavePartitionDuplicate { group } => {
                write!(f, "wave partition duplicate: sub-group {group} scheduled twice")
            }
            Violation::WaveUnknownGroup { wave, group } => {
                write!(f, "wave {wave} names unknown sub-group {group}")
            }
            Violation::WaveRowOverlap { wave, group_a, group_b, mode, row } => write!(
                f,
                "wave {wave}: sub-groups {group_a} and {group_b} both touch mode-{mode} row {row}"
            ),
            Violation::WaveOrderInversion { group_a, group_b, wave_a, wave_b, mode, row } => {
                write!(
                    f,
                    "order inversion: sub-group {group_a} (wave {wave_a}) conflicts with \
                     {group_b} (wave {wave_b}) on mode-{mode} row {row} but runs later"
                )
            }
            Violation::LatinMalformedAssignment { round, worker } => {
                write!(f, "round {round}: worker {worker} has a malformed block assignment")
            }
            Violation::LatinRowOverlap { round, mode, worker_a, worker_b, row } => write!(
                f,
                "round {round}: workers {worker_a} and {worker_b} both own mode-{mode} row {row}"
            ),
            Violation::LatinBlockRevisited { round, worker } => {
                write!(f, "round {round}: worker {worker} revisits an already-covered block")
            }
            Violation::LatinCoverageGap { block } => {
                write!(f, "latin cycle never visits block {block:?}")
            }
            Violation::DeviceWorkerGap { worker } => {
                write!(f, "worker {worker} belongs to no device")
            }
            Violation::DeviceWorkerOverlap { worker, device_a, device_b } => {
                write!(f, "worker {worker} belongs to devices {device_a} and {device_b}")
            }
            Violation::OwnershipGap { mode, row } => {
                write!(f, "mode-{mode} row {row} is homed on no device")
            }
            Violation::OwnershipOverlap { mode, row, device_a, device_b } => write!(
                f,
                "mode-{mode} row {row} is homed on devices {device_a} and {device_b}"
            ),
            Violation::OwnershipMismatch { device, mode } => write!(
                f,
                "device {device}: owned mode-{mode} rows differ from its workers' chunk union"
            ),
            Violation::NnzDeviceMismatch { nnz, assigned, expected } => write!(
                f,
                "nonzero {nnz} assigned to device {assigned}, mode-0 row owner is {expected}"
            ),
            Violation::BoundaryMissing { device, round, mode, chunk } => write!(
                f,
                "device {device} round {round}: remote mode-{mode} chunk {chunk} missing \
                 from boundary set"
            ),
            Violation::BoundarySpurious { device, round, mode, chunk } => write!(
                f,
                "device {device} round {round}: boundary set lists mode-{mode} chunk {chunk} \
                 it does not need"
            ),
            Violation::ExchangeApplyBeforeBarrier { epoch, round, seq } => write!(
                f,
                "epoch {epoch} round {round}: panel seq {seq} applied before the exchange \
                 window opened"
            ),
            Violation::ExchangeApplyAfterCompute { epoch, round, seq } => write!(
                f,
                "epoch {epoch} round {round}: panel seq {seq} applied after the workers \
                 were released"
            ),
            Violation::ExchangeDuplicateApply { seq } => {
                write!(f, "panel seq {seq} applied twice")
            }
            Violation::ExchangePhantomApply { seq } => {
                write!(f, "panel seq {seq} applied but never delivered")
            }
            Violation::ExchangeUnappliedDelivery { epoch, round, seq } => write!(
                f,
                "epoch {epoch} round {round}: delivered panel seq {seq} was never applied"
            ),
            Violation::ExchangeStalenessExceeded { epoch, round, seq, late_by } => write!(
                f,
                "epoch {epoch} round {round}: panel seq {seq} applied {late_by} closed \
                 window(s) late, over the staleness bound"
            ),
        }
    }
}

/// Outcome of one audit: how many elementary facts were checked and
/// every violation found. `checks` exists so a green report can be told
/// apart from a vacuous one.
#[derive(Clone, Debug, Default)]
pub struct AuditReport {
    /// Number of elementary facts verified.
    pub checks: usize,
    /// Violations found, in discovery order.
    pub violations: Vec<Violation>,
}

impl AuditReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Fold another report into this one.
    pub fn merge(&mut self, other: AuditReport) {
        self.checks += other.checks;
        self.violations.extend(other.violations);
    }

    /// Panic with the full report when it is red (`strict-audit` hook).
    pub fn assert_clean(&self, what: &str) {
        assert!(self.ok(), "strict-audit: {what} failed the disjointness audit\n{self}");
    }
}

impl fmt::Display for AuditReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "audit: {} checks, {} violation(s)",
            self.checks,
            self.violations.len()
        )?;
        const SHOWN: usize = 16;
        for v in self.violations.iter().take(SHOWN) {
            writeln!(f, "  - {v}")?;
        }
        if self.violations.len() > SHOWN {
            writeln!(f, "  ... {} more", self.violations.len() - SHOWN)?;
        }
        Ok(())
    }
}

/// Row range `[lo, hi)` of chunk `c` when a `dim`-row mode is cut into
/// `m` ceil-sized chunks. Re-derived locally (NOT calling
/// `BlockPartition::chunk_range`) so the auditor stays independent of
/// the code under audit.
fn chunk_rows(c: usize, dim: usize, m: usize) -> (usize, usize) {
    let w = dim.div_ceil(m);
    ((c * w).min(dim), ((c + 1) * w).min(dim))
}

/// Chunk id of row `i` under the same ceil-split rule.
fn chunk_of_row(i: usize, dim: usize, m: usize) -> usize {
    (i / dim.div_ceil(m)).min(m - 1)
}

/// Extract the wave lists of a [`SubGroupColoring`] as plain data, so
/// the auditor (and the mutation tests) operate on values the coloring
/// code no longer controls.
pub fn waves_of(coloring: &SubGroupColoring) -> Vec<Vec<u32>> {
    (0..coloring.n_waves()).map(|w| coloring.wave(w).to_vec()).collect()
}

/// Level-2 audit: wave partition, same-wave row disjointness, and
/// plan-order preservation for conflicting pairs.
///
/// `waves[w]` lists the plan sub-group indices scheduled in wave `w`
/// (use [`waves_of`] on a real coloring). The conflict graph is built
/// by brute force from the tensor indices of every sample in every
/// sub-group — per-(mode,row) chains of touching groups in plan order
/// must have strictly increasing wave numbers: an equal pair is a
/// same-wave overlap, a decreasing pair is an order inversion.
pub fn audit_coloring(
    tensor: &SparseTensor,
    plan: &BatchPlan,
    waves: &[Vec<u32>],
) -> AuditReport {
    let mut report = AuditReport::default();
    let n_groups = plan.n_groups();

    // -- Partition: every sub-group in exactly one wave. --------------
    const NO_WAVE: usize = usize::MAX;
    let mut wave_of = vec![NO_WAVE; n_groups];
    for (w, wave) in waves.iter().enumerate() {
        for &g in wave {
            let g = g as usize;
            if g >= n_groups {
                report.violations.push(Violation::WaveUnknownGroup { wave: w, group: g });
                continue;
            }
            if wave_of[g] != NO_WAVE {
                report.violations.push(Violation::WavePartitionDuplicate { group: g });
            } else {
                wave_of[g] = w;
            }
            report.checks += 1;
        }
    }
    for (g, &w) in wave_of.iter().enumerate() {
        if w == NO_WAVE {
            report.violations.push(Violation::WavePartitionGap { group: g });
        }
    }

    // -- Conflict chains: per (mode, row), the groups touching it in
    //    plan order. Groups are visited ascending, so each chain is
    //    already plan-ordered. -----------------------------------------
    let order = tensor.order();
    let mut chains: BTreeMap<(usize, u32), Vec<usize>> = BTreeMap::new();
    let mut footprint: BTreeSet<(usize, u32)> = BTreeSet::new();
    for g in 0..n_groups {
        footprint.clear();
        for &id in plan.group(g) {
            let ix = tensor.index(id as usize);
            for (mode, &row) in ix.iter().enumerate().take(order) {
                footprint.insert((mode, row));
            }
        }
        for &key in &footprint {
            chains.entry(key).or_default().push(g);
        }
    }

    // Strictly increasing waves along each plan-ordered chain imply the
    // property for every conflicting pair, so checking consecutive
    // chain neighbours suffices.
    for (&(mode, row), chain) in &chains {
        for pair in chain.windows(2) {
            let (a, b) = (pair[0], pair[1]);
            let (wa, wb) = (wave_of[a], wave_of[b]);
            if wa == NO_WAVE || wb == NO_WAVE {
                continue; // already reported as a partition gap
            }
            report.checks += 1;
            if wa == wb {
                report.violations.push(Violation::WaveRowOverlap {
                    wave: wa,
                    group_a: a,
                    group_b: b,
                    mode,
                    row: row as usize,
                });
            } else if wa > wb {
                report.violations.push(Violation::WaveOrderInversion {
                    group_a: a,
                    group_b: b,
                    wave_a: wa,
                    wave_b: wb,
                    mode,
                    row: row as usize,
                });
            }
        }
    }
    report
}

/// Level-1 audit: within every round the workers' blocks are pairwise
/// row-disjoint in every mode; over a full cycle every block is visited
/// exactly once.
///
/// `rounds[t][g]` is worker `g`'s chunk-coordinate vector in round `t`
/// (use [`LatinSchedule::round_assignments`] to gather it). Coverage is
/// only checked when `rounds.len() * workers == workers^order`, i.e.
/// when handed a full cycle.
pub fn audit_latin(dims: &[usize], workers: usize, rounds: &[Vec<Vec<usize>>]) -> AuditReport {
    let mut report = AuditReport::default();
    let order = dims.len();
    let mut visited: BTreeSet<Vec<usize>> = BTreeSet::new();

    for (t, round) in rounds.iter().enumerate() {
        for (g, coords) in round.iter().enumerate() {
            if coords.len() != order || coords.iter().any(|&c| c >= workers) {
                report.violations.push(Violation::LatinMalformedAssignment {
                    round: t,
                    worker: g,
                });
                continue;
            }
            report.checks += 1;
            if !visited.insert(coords.clone()) {
                report.violations.push(Violation::LatinBlockRevisited { round: t, worker: g });
            }
        }
        // Row disjointness: in each mode, materialize every worker's
        // row range and check pairwise intersections (brute force over
        // worker pairs — worker counts are small).
        for (mode, &dim) in dims.iter().enumerate() {
            let ranges: Vec<(usize, (usize, usize))> = round
                .iter()
                .enumerate()
                .filter(|(_, coords)| coords.len() == order)
                .map(|(g, coords)| (g, chunk_rows(coords[mode], dim, workers)))
                .collect();
            for (i, &(ga, (alo, ahi))) in ranges.iter().enumerate() {
                for &(gb, (blo, bhi)) in ranges.iter().skip(i + 1) {
                    report.checks += 1;
                    let lo = alo.max(blo);
                    let hi = ahi.min(bhi);
                    if lo < hi {
                        report.violations.push(Violation::LatinRowOverlap {
                            round: t,
                            mode,
                            worker_a: ga,
                            worker_b: gb,
                            row: lo,
                        });
                    }
                }
            }
        }
    }

    // Coverage, only for a full cycle.
    let full_cycle = workers
        .checked_pow(order as u32)
        .is_some_and(|blocks| rounds.len() * workers == blocks);
    if full_cycle {
        let mut coords = vec![0usize; order];
        loop {
            report.checks += 1;
            if !visited.contains(&coords) {
                report.violations.push(Violation::LatinCoverageGap { block: coords.clone() });
            }
            // Odometer increment over the block coordinate space.
            let mut n = 0;
            while n < order {
                coords[n] += 1;
                if coords[n] < workers {
                    break;
                }
                coords[n] = 0;
                n += 1;
            }
            if n == order {
                break;
            }
        }
    }
    report
}

/// Plain-data snapshot of a device grid + schedule, decoupled from the
/// builders so mutation tests can corrupt individual facts.
#[derive(Clone, Debug)]
pub struct GridFacts {
    /// Factor mode sizes.
    pub dims: Vec<usize>,
    /// Latin worker count (grid columns).
    pub workers: usize,
    /// Per-device worker range `[start, end)`.
    pub device_workers: Vec<(usize, usize)>,
    /// `owned_rows[d][mode]` = row range `[lo, hi)` homed on device `d`.
    pub owned_rows: Vec<Vec<(usize, usize)>>,
    /// Device each nonzero was assigned to.
    pub nnz_device: Vec<usize>,
    /// Mode-0 row of each nonzero.
    pub nnz_row0: Vec<u32>,
    /// `boundaries[t][d]` = `(mode, chunk)` pairs device `d` must fetch
    /// in round `t`.
    pub boundaries: Vec<Vec<Vec<(usize, usize)>>>,
    /// `rounds[t][g]` = worker `g`'s chunk coordinates in round `t`.
    pub rounds: Vec<Vec<Vec<usize>>>,
}

/// Gather [`GridFacts`] from live objects through their public API.
pub fn gather_grid_facts(
    grid: &DeviceGrid,
    schedule: &LatinSchedule,
    tensor: &SparseTensor,
) -> GridFacts {
    let devices = grid.devices();
    let rounds: Vec<Vec<Vec<usize>>> =
        (0..schedule.rounds()).map(|t| schedule.round_assignments(t)).collect();
    GridFacts {
        dims: tensor.dims().to_vec(),
        workers: grid.workers(),
        device_workers: (0..devices)
            .map(|d| {
                let r = grid.workers_of(d);
                (r.start, r.end)
            })
            .collect(),
        owned_rows: (0..devices)
            .map(|d| (0..tensor.order()).map(|n| grid.owned_rows(d, n)).collect())
            .collect(),
        nnz_device: (0..tensor.nnz()).map(|k| grid.device_of_nnz(tensor, k)).collect(),
        nnz_row0: (0..tensor.nnz()).map(|k| tensor.index(k)[0]).collect(),
        boundaries: (0..schedule.rounds())
            .map(|t| (0..devices).map(|d| grid.boundary_chunks(schedule, t, d)).collect())
            .collect(),
        rounds,
    }
}

/// Level-0 audit over [`GridFacts`]: worker-range partition, ownership
/// tiling, nonzero placement, and boundary/home complementarity.
pub fn audit_grid(facts: &GridFacts) -> AuditReport {
    let mut report = AuditReport::default();
    let devices = facts.device_workers.len();

    // -- Worker ranges partition 0..workers. --------------------------
    const NO_DEV: usize = usize::MAX;
    let mut device_of_worker = vec![NO_DEV; facts.workers];
    for (d, &(lo, hi)) in facts.device_workers.iter().enumerate() {
        for g in lo..hi.min(facts.workers) {
            report.checks += 1;
            if device_of_worker[g] != NO_DEV {
                report.violations.push(Violation::DeviceWorkerOverlap {
                    worker: g,
                    device_a: device_of_worker[g],
                    device_b: d,
                });
            } else {
                device_of_worker[g] = d;
            }
        }
    }
    for (g, &d) in device_of_worker.iter().enumerate() {
        if d == NO_DEV {
            report.violations.push(Violation::DeviceWorkerGap { worker: g });
        }
    }

    // -- Ownership tiles every mode exactly, and matches the union of
    //    each device's worker chunk ranges. ---------------------------
    for (mode, &dim) in facts.dims.iter().enumerate() {
        // Brute force per row: count owning devices.
        for row in 0..dim {
            report.checks += 1;
            let mut owner = NO_DEV;
            for (d, ranges) in facts.owned_rows.iter().enumerate() {
                let (lo, hi) = ranges[mode];
                if (lo..hi).contains(&row) {
                    if owner != NO_DEV {
                        report.violations.push(Violation::OwnershipOverlap {
                            mode,
                            row,
                            device_a: owner,
                            device_b: d,
                        });
                    } else {
                        owner = d;
                    }
                }
            }
            if owner == NO_DEV {
                report.violations.push(Violation::OwnershipGap { mode, row });
            }
        }
        for (d, &(wlo, whi)) in facts.device_workers.iter().enumerate() {
            report.checks += 1;
            let expected = if wlo >= whi {
                (0, 0)
            } else {
                (
                    chunk_rows(wlo, dim, facts.workers).0,
                    chunk_rows(whi - 1, dim, facts.workers).1,
                )
            };
            let got = facts.owned_rows[d][mode];
            let empty = |r: (usize, usize)| r.0 >= r.1;
            if got != expected && !(empty(got) && empty(expected)) {
                report.violations.push(Violation::OwnershipMismatch { device: d, mode });
            }
        }
    }

    // -- Every nonzero on exactly one device: the owner of its mode-0
    //    chunk (mode-0 chunks are worker-pinned). ----------------------
    for (k, (&assigned, &row0)) in
        facts.nnz_device.iter().zip(facts.nnz_row0.iter()).enumerate()
    {
        report.checks += 1;
        let worker = chunk_of_row(row0 as usize, facts.dims[0], facts.workers);
        let expected = device_of_worker.get(worker).copied().unwrap_or(NO_DEV);
        if assigned != expected {
            report.violations.push(Violation::NnzDeviceMismatch {
                nnz: k,
                assigned,
                expected,
            });
        }
    }

    // -- Boundary sets: exactly the touched-but-not-homed chunks. -----
    for (t, per_device) in facts.boundaries.iter().enumerate() {
        let Some(round) = facts.rounds.get(t) else { continue };
        for (d, given) in per_device.iter().enumerate().take(devices) {
            let (wlo, whi) = facts.device_workers[d];
            let mut expected: BTreeSet<(usize, usize)> = BTreeSet::new();
            for (g, coords) in round.iter().enumerate() {
                if g < wlo || g >= whi {
                    continue;
                }
                for (mode, &chunk) in coords.iter().enumerate() {
                    // Homed iff the chunk's worker column lies in this
                    // device's range (chunk c of any mode is worker c's
                    // home column).
                    if chunk < wlo || chunk >= whi {
                        expected.insert((mode, chunk));
                    }
                }
            }
            let given_set: BTreeSet<(usize, usize)> = given.iter().copied().collect();
            for &(mode, chunk) in expected.difference(&given_set) {
                report.violations.push(Violation::BoundaryMissing { device: d, round: t, mode, chunk });
            }
            for &(mode, chunk) in given_set.difference(&expected) {
                report.violations.push(Violation::BoundarySpurious { device: d, round: t, mode, chunk });
            }
            report.checks += expected.len().max(1);
        }
    }
    report
}

/// In-flight-exchange audit over a transport event log: every applied
/// panel lands strictly inside its round's barrier window, sequence
/// numbers are applied exactly once, and every delivery is consumed.
///
/// The checker is a plain linear scan over the log — it shares no state
/// with the [`Exchanger`](crate::parallel::transport::Exchanger) that
/// emitted it, so a protocol bug in the driver cannot hide inside the
/// audit. Tolerated by design: a `ComputeStart` with no `BarrierStart`
/// (rounds that shipped no panels skip the window entirely), and `Sent`
/// frames that never arrive (drops/kills are the *transport's* problem;
/// this leg audits only what was claimed delivered and applied).
pub fn audit_exchange(events: &[ExchangeEvent]) -> AuditReport {
    audit_exchange_with_staleness(events, 0)
}

/// [`audit_exchange`] with the relaxed bounded-staleness contract of
/// async prefetch (ISSUE 8): a delivered panel may be applied up to
/// `max_staleness` closed exchange windows after its own. Concretely,
/// when a panel of round `r` is applied, the number of `ComputeStart`
/// events already seen for rounds `>= r` of the same epoch is its
/// lateness; lateness above the bound raises
/// [`Violation::ExchangeStalenessExceeded`] (or, at `max_staleness = 0`
/// where no apply may ever leave its own window,
/// [`Violation::ExchangeApplyAfterCompute`] — the strict exact-mode
/// reading). Unapplied-delivery detection defers the same way: a
/// pending delivery is only overdue once the window `max_staleness`
/// rounds past its own closes (or the log ends). The pipelined
/// transfer itself needs no tolerance carve-out — `Sent`/`Delivered`
/// events landing before their round's `BarrierStart` were never
/// violations; the window constrains the *apply*.
pub fn audit_exchange_with_staleness(
    events: &[ExchangeEvent],
    max_staleness: usize,
) -> AuditReport {
    let mut report = AuditReport::default();
    let mut started: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut computed: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut applied: BTreeSet<u64> = BTreeSet::new();
    // Delivered but not yet applied: seq -> (epoch, round).
    let mut pending: BTreeMap<u64, (usize, usize)> = BTreeMap::new();
    for ev in events {
        match *ev {
            ExchangeEvent::BarrierStart { epoch, round } => {
                report.checks += 1;
                started.insert((epoch, round));
            }
            ExchangeEvent::Sent { .. } => report.checks += 1,
            ExchangeEvent::Delivered { epoch, round, seq, .. } => {
                report.checks += 1;
                pending.insert(seq, (epoch, round));
            }
            ExchangeEvent::Applied { epoch, round, seq, .. } => {
                report.checks += 1;
                if !started.contains(&(epoch, round)) {
                    report
                        .violations
                        .push(Violation::ExchangeApplyBeforeBarrier { epoch, round, seq });
                }
                // Lateness: closed windows at-or-after the panel's own.
                let late_by =
                    computed.range((epoch, round)..=(epoch, usize::MAX)).count();
                if late_by > max_staleness {
                    report.violations.push(if max_staleness == 0 {
                        Violation::ExchangeApplyAfterCompute { epoch, round, seq }
                    } else {
                        Violation::ExchangeStalenessExceeded { epoch, round, seq, late_by }
                    });
                }
                if applied.contains(&seq) {
                    report.violations.push(Violation::ExchangeDuplicateApply { seq });
                } else {
                    applied.insert(seq);
                    if pending.remove(&seq).is_none() {
                        report.violations.push(Violation::ExchangePhantomApply { seq });
                    }
                }
            }
            ExchangeEvent::ComputeStart { epoch, round } => {
                report.checks += 1;
                computed.insert((epoch, round));
                // A pending delivery of round r is overdue once this
                // close leaves it no legal later window: its apply after
                // this point would be > max_staleness windows late.
                let stale: Vec<u64> = pending
                    .iter()
                    .filter(|&(_, &(e, r))| {
                        e == epoch && round >= r + max_staleness
                    })
                    .map(|(&seq, _)| seq)
                    .collect();
                for seq in stale {
                    let (e, r) = pending.remove(&seq).unwrap();
                    report
                        .violations
                        .push(Violation::ExchangeUnappliedDelivery { epoch: e, round: r, seq });
                }
            }
        }
    }
    // Deliveries still pending when the log ends were never consumed.
    for (&seq, &(epoch, round)) in &pending {
        report.violations.push(Violation::ExchangeUnappliedDelivery { epoch, round, seq });
    }
    report
}

/// Run the level-0 and level-1 audits for a live grid + schedule over
/// `tensor` and merge the reports (the `strict-audit` engine hook and
/// the `audit_plan` binary both call this).
pub fn audit_schedule_and_grid(
    grid: &DeviceGrid,
    schedule: &LatinSchedule,
    tensor: &SparseTensor,
) -> AuditReport {
    let facts = gather_grid_facts(grid, schedule, tensor);
    let mut report = audit_latin(&facts.dims, facts.workers, &facts.rounds);
    report.merge(audit_grid(&facts));
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kernel::PlanParams;
    use crate::util::propcheck::forall;
    use crate::util::Rng;

    fn workload(rng: &mut Rng, dims: &[usize], nnz: usize) -> SparseTensor {
        synth::random_uniform(rng, dims, nnz, 1.0, 5.0)
    }

    fn exact_plan(t: &SparseTensor, cap: usize, tile: usize, split: usize) -> BatchPlan {
        let ids: Vec<u32> = (0..t.nnz() as u32).collect();
        BatchPlan::build_params(t, &ids, PlanParams::tiled(cap, tile).with_split(split))
    }

    #[test]
    fn real_colorings_audit_green() {
        forall("auditor accepts real colorings", 16, |rng| {
            let order = 2 + rng.gen_range(2);
            let dims: Vec<usize> = (0..order).map(|_| 8 + rng.gen_range(40)).collect();
            let t = workload(rng, &dims, 200 + rng.gen_range(400));
            let plan = exact_plan(&t, 4 + rng.gen_range(28), 4, 1 + rng.gen_range(4));
            let coloring = plan.color_subgroups(&t);
            let report = audit_coloring(&t, &plan, &waves_of(&coloring));
            assert!(report.ok(), "{report}");
            assert!(report.checks > 0, "vacuous audit");
        });
    }

    #[test]
    fn merged_conflicting_waves_are_caught() {
        // Mutation: pull a group from a later wave into wave 0. The
        // greedy coloring only defers a group when it conflicts with an
        // earlier one, so the merge must produce a WaveRowOverlap (the
        // chain neighbour case) for some shared row.
        let mut rng = Rng::new(7);
        let t = workload(&mut rng, &[24, 10, 10], 600);
        let plan = exact_plan(&t, 8, 4, 1);
        let coloring = plan.color_subgroups(&t);
        let mut waves = waves_of(&coloring);
        assert!(waves.len() >= 2, "need a conflict to corrupt (got {} waves)", waves.len());
        // Move the first group of wave 1 into wave 0 (keep ascending
        // order inside the wave so only the disjointness breaks).
        let moved = waves[1].remove(0);
        let pos = waves[0].partition_point(|&g| g < moved);
        waves[0].insert(pos, moved);
        let report = audit_coloring(&t, &plan, &waves);
        assert!(
            report
                .violations
                .iter()
                .any(|v| matches!(v, Violation::WaveRowOverlap { .. })),
            "expected WaveRowOverlap, got: {report}"
        );
    }

    #[test]
    fn inverted_wave_order_is_caught() {
        // Mutation: swap the waves of a conflicting pair entirely. A
        // group from wave 0 moved *after* its wave-1 conflictor breaks
        // plan-order preservation.
        let mut rng = Rng::new(11);
        let t = workload(&mut rng, &[24, 10, 10], 600);
        let plan = exact_plan(&t, 8, 4, 1);
        let coloring = plan.color_subgroups(&t);
        let mut waves = waves_of(&coloring);
        assert!(waves.len() >= 2);
        // The greedy pass put the *first* wave-1 group there because it
        // conflicts with some wave-0 group that precedes it in plan
        // order. Swapping the two waves wholesale therefore inverts at
        // least one conflicting pair.
        waves.swap(0, 1);
        let report = audit_coloring(&t, &plan, &waves);
        assert!(
            report
                .violations
                .iter()
                .any(|v| matches!(v, Violation::WaveOrderInversion { .. })),
            "expected WaveOrderInversion, got: {report}"
        );
    }

    #[test]
    fn dropped_and_duplicated_groups_are_caught() {
        let mut rng = Rng::new(3);
        let t = workload(&mut rng, &[24, 10, 10], 300);
        let plan = exact_plan(&t, 8, 4, 1);
        let coloring = plan.color_subgroups(&t);
        let mut waves = waves_of(&coloring);
        let victim = waves[0].pop().expect("wave 0 nonempty");
        let report = audit_coloring(&t, &plan, &waves);
        assert!(report
            .violations
            .iter()
            .any(|v| *v == Violation::WavePartitionGap { group: victim as usize }));

        let mut waves = waves_of(&coloring);
        let dup = waves[0][0];
        waves.last_mut().unwrap().push(dup);
        let report = audit_coloring(&t, &plan, &waves);
        assert!(report
            .violations
            .iter()
            .any(|v| *v == Violation::WavePartitionDuplicate { group: dup as usize }));
    }

    #[test]
    fn real_latin_schedules_audit_green() {
        forall("auditor accepts real latin schedules", 24, |rng| {
            let order = 2 + rng.gen_range(3);
            let m = 1 + rng.gen_range(5);
            let dims: Vec<usize> = (0..order).map(|_| 5 + rng.gen_range(30)).collect();
            let s = LatinSchedule::new(m, order);
            let rounds: Vec<Vec<Vec<usize>>> =
                (0..s.rounds()).map(|t| s.round_assignments(t)).collect();
            let report = audit_latin(&dims, m, &rounds);
            assert!(report.ok(), "{report}");
            assert!(report.checks > 0);
        });
    }

    #[test]
    fn duplicated_latin_chunk_is_caught() {
        // Mutation: give worker 1 the same mode-1 chunk as worker 0.
        let s = LatinSchedule::new(3, 3);
        let mut rounds: Vec<Vec<Vec<usize>>> =
            (0..s.rounds()).map(|t| s.round_assignments(t)).collect();
        rounds[0][1][1] = rounds[0][0][1];
        let report = audit_latin(&[30, 30, 30], 3, &rounds);
        assert!(
            report.violations.iter().any(|v| matches!(
                v,
                Violation::LatinRowOverlap { round: 0, mode: 1, worker_a: 0, worker_b: 1, .. }
            )),
            "expected LatinRowOverlap, got: {report}"
        );
        // The mutated cycle also fails coverage: the orphaned block is
        // never visited.
        assert!(report
            .violations
            .iter()
            .any(|v| matches!(v, Violation::LatinCoverageGap { .. })));
    }

    #[test]
    fn real_grids_audit_green() {
        forall("auditor accepts real device grids", 16, |rng| {
            let order = 2 + rng.gen_range(2);
            let workers = 2 + rng.gen_range(5);
            let devices = 1 + rng.gen_range(workers.min(4));
            let dims: Vec<usize> = (0..order).map(|_| workers + rng.gen_range(40)).collect();
            let t = workload(rng, &dims, 300);
            let g = DeviceGrid::try_new(
                crate::parallel::DeviceCount::Fixed(devices),
                workers,
                &dims,
            )
            .unwrap();
            let s = LatinSchedule::new(workers, order);
            let report = audit_schedule_and_grid(&g, &s, &t);
            assert!(report.ok(), "{report}");
            assert!(report.checks > 0);
        });
    }

    #[test]
    fn dropped_boundary_chunk_is_caught() {
        let dims = [40usize, 40, 40];
        let workers = 4;
        let t = {
            let mut rng = Rng::new(5);
            workload(&mut rng, &dims, 400)
        };
        let g = DeviceGrid::try_new(crate::parallel::DeviceCount::Fixed(2), workers, &dims).unwrap();
        let s = LatinSchedule::new(workers, 3);
        let mut facts = gather_grid_facts(&g, &s, &t);
        // Mutation: drop one boundary chunk from a round that has any.
        let (t_ix, d_ix) = (1..facts.boundaries.len())
            .flat_map(|t| (0..facts.boundaries[t].len()).map(move |d| (t, d)))
            .find(|&(t, d)| !facts.boundaries[t][d].is_empty())
            .expect("some round needs remote chunks");
        let dropped = facts.boundaries[t_ix][d_ix].pop().unwrap();
        let report = audit_grid(&facts);
        assert!(
            report.violations.iter().any(|v| *v
                == Violation::BoundaryMissing {
                    device: d_ix,
                    round: t_ix,
                    mode: dropped.0,
                    chunk: dropped.1
                }),
            "expected BoundaryMissing for {dropped:?}, got: {report}"
        );
    }

    #[test]
    fn corrupted_ownership_and_placement_are_caught() {
        let dims = [40usize, 40, 40];
        let t = {
            let mut rng = Rng::new(9);
            workload(&mut rng, &dims, 200)
        };
        let g = DeviceGrid::try_new(crate::parallel::DeviceCount::Fixed(2), 4, &dims).unwrap();
        let s = LatinSchedule::new(4, 3);

        // Shrink device 0's mode-0 ownership: rows fall off both the
        // tiling and the worker-chunk union.
        let mut facts = gather_grid_facts(&g, &s, &t);
        facts.owned_rows[0][0].1 -= 1;
        let report = audit_grid(&facts);
        assert!(report.violations.iter().any(|v| matches!(v, Violation::OwnershipGap { .. })));
        assert!(report
            .violations
            .iter()
            .any(|v| *v == Violation::OwnershipMismatch { device: 0, mode: 0 }));

        // Reassign one nonzero to the wrong device.
        let mut facts = gather_grid_facts(&g, &s, &t);
        let k = 0;
        facts.nnz_device[k] = 1 - facts.nnz_device[k];
        let report = audit_grid(&facts);
        assert!(report
            .violations
            .iter()
            .any(|v| matches!(v, Violation::NnzDeviceMismatch { nnz: 0, .. })));

        // Overlap the worker ranges.
        let mut facts = gather_grid_facts(&g, &s, &t);
        facts.device_workers[1].0 -= 1;
        let report = audit_grid(&facts);
        assert!(report
            .violations
            .iter()
            .any(|v| matches!(v, Violation::DeviceWorkerOverlap { .. })));
    }

    // ---- In-flight exchange leg (ISSUE 7 satellite) -----------------

    /// One well-formed exchange window: barrier, sends, deliveries,
    /// applies, release.
    fn healthy_window(epoch: usize, round: usize, seqs: &[u64]) -> Vec<ExchangeEvent> {
        let mut evs = vec![ExchangeEvent::BarrierStart { epoch, round }];
        for &seq in seqs {
            evs.push(ExchangeEvent::Sent { epoch, round, src: 0, dst: 1, mode: 0, chunk: 0, seq });
        }
        for &seq in seqs {
            evs.push(ExchangeEvent::Delivered {
                epoch,
                round,
                src: 0,
                dst: 1,
                mode: 0,
                chunk: 0,
                seq,
            });
        }
        for &seq in seqs {
            evs.push(ExchangeEvent::Applied { epoch, round, dst: 1, mode: 0, chunk: 0, seq });
        }
        evs.push(ExchangeEvent::ComputeStart { epoch, round });
        evs
    }

    #[test]
    fn healthy_exchange_log_audits_green() {
        let mut evs = healthy_window(0, 0, &[0, 1, 2]);
        // A panel-free round: ComputeStart with no BarrierStart must be
        // tolerated — the exchanger skips the window when nothing ships.
        evs.push(ExchangeEvent::ComputeStart { epoch: 0, round: 1 });
        evs.extend(healthy_window(0, 2, &[3, 4]));
        let report = audit_exchange(&evs);
        assert!(report.ok(), "{report}");
        assert!(report.checks > 0, "vacuous audit");
    }

    #[test]
    fn exchange_mutations_each_raise_their_named_violation() {
        // Mutation per variant: corrupt one healthy log in one way and
        // demand exactly the matching violation class.
        let base = || healthy_window(0, 0, &[0, 1]);

        // Apply before its barrier: prepend an apply for round 1.
        let mut evs = vec![ExchangeEvent::Applied {
            epoch: 0,
            round: 1,
            dst: 1,
            mode: 0,
            chunk: 0,
            seq: 9,
        }];
        evs.extend(base());
        let report = audit_exchange(&evs);
        assert!(
            report.violations.iter().any(|v| matches!(
                v,
                Violation::ExchangeApplyBeforeBarrier { epoch: 0, round: 1, seq: 9 }
            )),
            "expected ExchangeApplyBeforeBarrier, got: {report}"
        );

        // Apply after the workers were released: re-apply seq 2 of a
        // second window after its ComputeStart.
        let mut evs = base();
        evs.push(ExchangeEvent::BarrierStart { epoch: 0, round: 1 });
        evs.push(ExchangeEvent::Delivered {
            epoch: 0,
            round: 1,
            src: 0,
            dst: 1,
            mode: 0,
            chunk: 0,
            seq: 2,
        });
        evs.push(ExchangeEvent::ComputeStart { epoch: 0, round: 1 });
        evs.push(ExchangeEvent::Applied { epoch: 0, round: 1, dst: 1, mode: 0, chunk: 0, seq: 2 });
        let report = audit_exchange(&evs);
        assert!(
            report.violations.iter().any(|v| matches!(
                v,
                Violation::ExchangeApplyAfterCompute { epoch: 0, round: 1, seq: 2 }
            )),
            "expected ExchangeApplyAfterCompute, got: {report}"
        );
        // The same mutated log also flags the delivery as unapplied at
        // ComputeStart time (the late apply does not retroactively count).
        assert!(report
            .violations
            .iter()
            .any(|v| matches!(v, Violation::ExchangeUnappliedDelivery { seq: 2, .. })));

        // Duplicate apply of one seq.
        let mut evs = base();
        evs.insert(
            evs.len() - 1,
            ExchangeEvent::Applied { epoch: 0, round: 0, dst: 1, mode: 0, chunk: 0, seq: 0 },
        );
        let report = audit_exchange(&evs);
        assert!(
            report
                .violations
                .iter()
                .any(|v| matches!(v, Violation::ExchangeDuplicateApply { seq: 0 })),
            "expected ExchangeDuplicateApply, got: {report}"
        );

        // Phantom apply: a seq never delivered.
        let mut evs = base();
        evs.insert(
            evs.len() - 1,
            ExchangeEvent::Applied { epoch: 0, round: 0, dst: 1, mode: 0, chunk: 0, seq: 77 },
        );
        let report = audit_exchange(&evs);
        assert!(
            report
                .violations
                .iter()
                .any(|v| matches!(v, Violation::ExchangePhantomApply { seq: 77 })),
            "expected ExchangePhantomApply, got: {report}"
        );

        // Unapplied delivery, both at ComputeStart and at end-of-log.
        let mut evs = base();
        let apply_ix = evs
            .iter()
            .position(|e| matches!(e, ExchangeEvent::Applied { seq: 1, .. }))
            .unwrap();
        evs.remove(apply_ix);
        let report = audit_exchange(&evs);
        assert!(
            report.violations.iter().any(|v| matches!(
                v,
                Violation::ExchangeUnappliedDelivery { epoch: 0, round: 0, seq: 1 }
            )),
            "expected ExchangeUnappliedDelivery, got: {report}"
        );
        let evs = vec![
            ExchangeEvent::BarrierStart { epoch: 0, round: 0 },
            ExchangeEvent::Delivered { epoch: 0, round: 0, src: 0, dst: 1, mode: 0, chunk: 0, seq: 5 },
        ];
        let report = audit_exchange(&evs);
        assert!(report
            .violations
            .iter()
            .any(|v| matches!(v, Violation::ExchangeUnappliedDelivery { seq: 5, .. })));
    }

    #[test]
    fn pipelined_transfer_before_barrier_is_tolerated() {
        // ISSUE 8 pipelining leg: under async prefetch the next round's
        // frames are sent — and can arrive — while the previous round
        // still computes, so Sent/Delivered legally precede their
        // round's BarrierStart. Only the *apply* is window-bound.
        let mut evs = vec![ExchangeEvent::BarrierStart { epoch: 0, round: 0 }];
        // Round 1's transfer pipelines inside round 0's window.
        evs.push(ExchangeEvent::Sent { epoch: 0, round: 1, src: 0, dst: 1, mode: 0, chunk: 0, seq: 8 });
        evs.push(ExchangeEvent::Delivered {
            epoch: 0,
            round: 1,
            src: 0,
            dst: 1,
            mode: 0,
            chunk: 0,
            seq: 8,
        });
        evs.push(ExchangeEvent::ComputeStart { epoch: 0, round: 0 });
        evs.push(ExchangeEvent::BarrierStart { epoch: 0, round: 1 });
        evs.push(ExchangeEvent::Applied { epoch: 0, round: 1, dst: 1, mode: 0, chunk: 0, seq: 8 });
        evs.push(ExchangeEvent::ComputeStart { epoch: 0, round: 1 });
        let report = audit_exchange(&evs);
        assert!(report.ok(), "pipelined transfer wrongly flagged: {report}");
    }

    /// A round-`r` window whose panel is delivered in-window but applied
    /// `late` windows later (each intervening window closes empty).
    fn staleness_log(late: usize) -> Vec<ExchangeEvent> {
        let mut evs = vec![
            ExchangeEvent::BarrierStart { epoch: 0, round: 0 },
            ExchangeEvent::Sent { epoch: 0, round: 0, src: 0, dst: 1, mode: 0, chunk: 0, seq: 3 },
            ExchangeEvent::Delivered {
                epoch: 0,
                round: 0,
                src: 0,
                dst: 1,
                mode: 0,
                chunk: 0,
                seq: 3,
            },
        ];
        for r in 0..late {
            evs.push(ExchangeEvent::ComputeStart { epoch: 0, round: r });
            evs.push(ExchangeEvent::BarrierStart { epoch: 0, round: r + 1 });
        }
        evs.push(ExchangeEvent::Applied { epoch: 0, round: 0, dst: 1, mode: 0, chunk: 0, seq: 3 });
        evs.push(ExchangeEvent::ComputeStart { epoch: 0, round: late });
        evs
    }

    #[test]
    fn staleness_auditor_accepts_bounded_and_flags_excess_lateness() {
        // An apply `late` closed windows after its own round is legal
        // exactly when late <= S; one window further raises the named
        // staleness violation, and the strict S = 0 form keeps raising
        // the exact-mode ApplyAfterCompute on any lateness at all.
        for s in [1usize, 2] {
            let report = audit_exchange_with_staleness(&staleness_log(s), s);
            assert!(report.ok(), "S={s}: bounded lateness wrongly flagged: {report}");
            let report = audit_exchange_with_staleness(&staleness_log(s + 1), s);
            assert!(
                report.violations.iter().any(|v| matches!(
                    v,
                    Violation::ExchangeStalenessExceeded { epoch: 0, round: 0, seq: 3, .. }
                )),
                "S={s}: excess lateness not flagged: {report}"
            );
        }
        let report = audit_exchange(&staleness_log(1));
        assert!(
            report.violations.iter().any(|v| matches!(
                v,
                Violation::ExchangeApplyAfterCompute { epoch: 0, round: 0, seq: 3 }
            )),
            "strict form lost the exact-mode violation: {report}"
        );
    }

    #[test]
    fn staleness_auditor_defers_unapplied_delivery_by_the_bound() {
        // Delete the late apply: the delivery is overdue only once the
        // window S rounds past its own closes — the S = 0 auditor flags
        // it at its own ComputeStart, the relaxed one S windows later,
        // and an in-bound pending delivery at end-of-log is still
        // flagged (epochs are self-contained).
        let mut evs = staleness_log(1);
        let ix = evs.iter().position(|e| matches!(e, ExchangeEvent::Applied { .. })).unwrap();
        evs.remove(ix);
        let report = audit_exchange_with_staleness(&evs, 1);
        assert!(
            report.violations.iter().any(|v| matches!(
                v,
                Violation::ExchangeUnappliedDelivery { epoch: 0, round: 0, seq: 3 }
            )),
            "overdue delivery not flagged at the deferred close: {report}"
        );
        // Same log, larger bound: window 1's close is still inside the
        // bound, so the only flag is the end-of-log sweep.
        let report = audit_exchange_with_staleness(&evs, 2);
        assert_eq!(
            report.violations.len(),
            1,
            "expected only the end-of-log sweep: {report}"
        );
        assert!(matches!(
            report.violations[0],
            Violation::ExchangeUnappliedDelivery { epoch: 0, round: 0, seq: 3 }
        ));
    }

    #[test]
    fn real_async_engine_exchange_log_audits_green() {
        // ISSUE 8 acceptance: the live async-prefetch engine's event log
        // over a W=4 D=2 channel run — transfers pipelined ahead of
        // their windows, applies still at their own barriers — must pass
        // the strict (S = 0) auditor unchanged.
        use crate::model::TuckerModel;
        use crate::parallel::{
            DeviceCount, ParallelFastTucker, ParallelOptions, PrefetchMode, TransportKind,
        };
        let dims = [40usize, 30, 30];
        let mut rng = Rng::new(31);
        let t = workload(&mut rng, &dims, 3000);
        let mut model = TuckerModel::init_kruskal(&mut rng, &dims, 4, 3);
        let mut opts = ParallelOptions::default();
        opts.workers = 4;
        opts.devices = DeviceCount::Fixed(2);
        opts.transport = TransportKind::Channel;
        opts.prefetch = PrefetchMode::Async;
        let mut engine = ParallelFastTucker::new(opts);
        let mut rng2 = Rng::new(32);
        for epoch in 0..2 {
            engine.train_epoch(&mut model, &t, epoch, &mut rng2).unwrap();
        }
        let events = engine.exchange_events();
        assert!(!events.is_empty(), "async channel engine logged no exchange events");
        assert!(
            events.iter().any(|e| matches!(e, ExchangeEvent::Sent { .. })),
            "no frames pipelined"
        );
        let report = audit_exchange(events);
        assert!(report.ok(), "{report}");
        assert!(report.checks > 0);
    }

    #[test]
    fn real_channel_engine_exchange_log_audits_green() {
        // The live engine's event log over a W=4 D=2 channel run must
        // satisfy the protocol contract end to end.
        use crate::model::TuckerModel;
        use crate::parallel::{
            DeviceCount, ParallelFastTucker, ParallelOptions, TransportKind,
        };
        let dims = [40usize, 30, 30];
        let mut rng = Rng::new(21);
        let t = workload(&mut rng, &dims, 3000);
        let mut model = TuckerModel::init_kruskal(&mut rng, &dims, 4, 3);
        let mut opts = ParallelOptions::default();
        opts.workers = 4;
        opts.devices = DeviceCount::Fixed(2);
        opts.transport = TransportKind::Channel;
        let mut engine = ParallelFastTucker::new(opts);
        let mut rng2 = Rng::new(22);
        for epoch in 0..2 {
            engine.train_epoch(&mut model, &t, epoch, &mut rng2).unwrap();
        }
        let events = engine.exchange_events();
        assert!(!events.is_empty(), "channel engine logged no exchange events");
        let report = audit_exchange(events);
        assert!(report.ok(), "{report}");
        assert!(report.checks > 0);
    }

    #[test]
    fn report_display_names_violations() {
        let mut r = AuditReport::default();
        r.violations.push(Violation::WaveRowOverlap {
            wave: 2,
            group_a: 1,
            group_b: 5,
            mode: 0,
            row: 7,
        });
        let text = r.to_string();
        assert!(text.contains("wave 2"), "{text}");
        assert!(text.contains("row 7"), "{text}");
        assert!(!r.ok());
    }
}
