//! Shadow race detector for [`SharedFactors`] row accesses.
//!
//! The checker logic (ledger model, happens-before pass, contention
//! histogram) always compiles so it stays unit-testable; the *hooks*
//! inside `parallel/shared.rs`, `kernel/dispatch.rs`, and
//! `parallel/worker.rs` only exist under the `shadow-ledger` cargo
//! feature, and even then recording is inert until a test opens a
//! [`ShadowSession`]. A session snapshots every row access with full
//! provenance `(epoch, round, worker, wave, thread, mode, row, kind)`
//! into per-thread ledgers; [`ShadowSession::finish`] drains them into a
//! [`ShadowLog`].
//!
//! The happens-before model mirrors the engine's synchronization
//! structure instead of a general vector-clock race detector — that is
//! the point: the engine's *only* defenses are the three disjointness
//! levels plus barriers, so the check is exactly those rules
//! ([`ShadowLog::check`]):
//!
//! - **Latin level**: two different workers in the same `(epoch, round)`
//!   must not touch the same `(mode, row)` when either side writes —
//!   rounds are the units Latin disjointness protects, and barriers only
//!   separate *rounds*, not workers within one.
//! - **Wave level**: within one worker's `(epoch, round, wave)`, two
//!   different pool threads must not touch the same row when a plain
//!   (non-atomic) write is involved; waves are barrier-separated, so
//!   cross-wave overlap is ordered and legal.
//! - **Mixed access**: atomic (relaxed hogwild) and plain access to the
//!   same row from different threads of one wave is a torn-model bug
//!   even though each side is individually "safe".
//!
//! Atomic/atomic overlap is *not* a violation — it is hogwild by design;
//! [`ShadowLog::overlap_histogram`] turns it into the first measured
//! view of actual relaxed-mode contention (how many distinct threads
//! hit the same row within one wave).
//!
//! **Deliberate blind spot**: the coordinator-serial exchange accessors
//! (`SharedFactors::{row_exchange, row_mut_exchange}`, used by the
//! channel transport to serialize/apply boundary panels at the round
//! barrier) do NOT record into the ledger — no workers run at the
//! barrier, so any recording would land under a stale worker/round
//! context and report false Latin races. That leg of the contract is
//! covered by the transport's own event log instead
//! ([`crate::analysis::audit_exchange`]).
//!
//! [`SharedFactors`]: crate::parallel::SharedFactors

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// How a row was touched.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum AccessKind {
    /// Shared read (`SharedFactors::row`).
    Read,
    /// Exclusive plain write (`SharedFactors::row_mut`).
    Write,
    /// Relaxed atomic access (`SharedFactors::row_atomic`).
    Atomic,
}

impl AccessKind {
    fn writes(self) -> bool {
        !matches!(self, AccessKind::Read)
    }
}

/// Where an access came from, in engine coordinates.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct Provenance {
    pub epoch: u32,
    pub round: u32,
    pub worker: u32,
    pub wave: u32,
    pub thread: u32,
}

/// One recorded row access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Access {
    pub mode: u32,
    pub row: u32,
    pub kind: AccessKind,
    pub prov: Provenance,
}

/// A race the wave-structured happens-before pass found.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RaceViolation {
    /// Two workers of one round conflict on a row (Latin level broken).
    LatinRace { epoch: u32, round: u32, mode: u32, row: u32, worker_a: u32, worker_b: u32 },
    /// Two pool threads of one wave conflict on a row with a plain
    /// write involved (wave level broken).
    WaveRace { epoch: u32, round: u32, worker: u32, wave: u32, mode: u32, row: u32 },
    /// Atomic and plain access to one row from different threads of one
    /// wave.
    MixedAccessRace { epoch: u32, round: u32, worker: u32, wave: u32, mode: u32, row: u32 },
}

// ---------------------------------------------------------------------
// Recording machinery. Global state is deliberately tiny: an enabled
// flag, a session id (so stale thread-local ledgers from a previous
// session re-register instead of leaking records across sessions), the
// engine epoch/round (set from the coordinator thread), and a registry
// of every thread's ledger.
// ---------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static SESSION_ID: AtomicU64 = AtomicU64::new(0);
static EPOCH: AtomicU32 = AtomicU32::new(0);
static ROUND: AtomicU32 = AtomicU32::new(0);
static REGISTRY: Mutex<Vec<Arc<Mutex<Vec<Access>>>>> = Mutex::new(Vec::new());
static SESSION_LOCK: Mutex<()> = Mutex::new(());

/// Per-thread placement coordinates (worker / wave / pool-thread).
#[derive(Clone, Copy, Debug, Default)]
pub struct ThreadCtx {
    pub worker: u32,
    pub wave: u32,
    pub thread: u32,
}

thread_local! {
    static CTX: Cell<ThreadCtx> = Cell::new(ThreadCtx::default());
    static LEDGER: RefCell<Option<(u64, Arc<Mutex<Vec<Access>>>)>> = RefCell::new(None);
}

/// Set the engine epoch (coordinator thread, start of `train_epoch`).
pub fn set_epoch(epoch: usize) {
    EPOCH.store(epoch as u32, Ordering::Relaxed);
}

/// Set the Latin round (coordinator thread, start of each round).
pub fn set_round(round: usize) {
    ROUND.store(round as u32, Ordering::Relaxed);
}

/// Bind the current thread to Latin worker `worker` (round spawn).
pub fn set_worker(worker: usize) {
    CTX.with(|c| c.set(ThreadCtx { worker: worker as u32, wave: 0, thread: 0 }));
}

/// Set the current color wave on this thread (pool wave loop).
pub fn set_wave(wave: usize) {
    CTX.with(|c| {
        let mut ctx = c.get();
        ctx.wave = wave as u32;
        c.set(ctx);
    });
}

/// Adopt a parent worker's context on a pool thread, tagging it with
/// the pool-thread index.
pub fn adopt(parent: ThreadCtx, thread: usize) {
    CTX.with(|c| c.set(ThreadCtx { thread: thread as u32, ..parent }));
}

/// Snapshot this thread's context (captured before spawning the pool).
pub fn current_ctx() -> ThreadCtx {
    CTX.with(|c| c.get())
}

/// Record one row access. No-op unless a [`ShadowSession`] is active.
#[inline]
pub fn record(mode: usize, row: usize, kind: AccessKind) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let sid = SESSION_ID.load(Ordering::Relaxed);
    let ctx = current_ctx();
    let access = Access {
        mode: mode as u32,
        row: row as u32,
        kind,
        prov: Provenance {
            epoch: EPOCH.load(Ordering::Relaxed),
            round: ROUND.load(Ordering::Relaxed),
            worker: ctx.worker,
            wave: ctx.wave,
            thread: ctx.thread,
        },
    };
    LEDGER.with(|slot| {
        let mut slot = slot.borrow_mut();
        let stale = match &*slot {
            Some((id, _)) => *id != sid,
            None => true,
        };
        if stale {
            let ledger: Arc<Mutex<Vec<Access>>> = Arc::new(Mutex::new(Vec::new()));
            REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).push(ledger.clone());
            *slot = Some((sid, ledger));
        }
        if let Some((_, ledger)) = &*slot {
            ledger.lock().unwrap_or_else(|e| e.into_inner()).push(access);
        }
    });
}

/// An active recording session. Sessions are process-global and
/// serialized by an internal lock, so concurrently running tests queue
/// up instead of polluting each other's ledgers.
pub struct ShadowSession {
    _serialize: MutexGuard<'static, ()>,
}

impl ShadowSession {
    /// Start recording. Blocks until any other session finishes.
    pub fn begin() -> ShadowSession {
        let guard = SESSION_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).clear();
        SESSION_ID.fetch_add(1, Ordering::Relaxed);
        EPOCH.store(0, Ordering::Relaxed);
        ROUND.store(0, Ordering::Relaxed);
        ENABLED.store(true, Ordering::SeqCst);
        ShadowSession { _serialize: guard }
    }

    /// Stop recording and drain every thread's ledger. Call after the
    /// instrumented run has joined all its threads.
    pub fn finish(self) -> ShadowLog {
        ENABLED.store(false, Ordering::SeqCst);
        let ledgers = std::mem::take(&mut *REGISTRY.lock().unwrap_or_else(|e| e.into_inner()));
        let mut records = Vec::new();
        for ledger in ledgers {
            records.append(&mut ledger.lock().unwrap_or_else(|e| e.into_inner()));
        }
        ShadowLog { records }
    }
}

/// Everything one session recorded, plus the analysis passes.
#[derive(Clone, Debug, Default)]
pub struct ShadowLog {
    pub records: Vec<Access>,
}

impl ShadowLog {
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Distinct `(mode, row)` pairs that saw a write-ish access — the
    /// provenance row-set that must be identical across thread counts
    /// in exact mode.
    pub fn written_rows(&self) -> BTreeSet<(u32, u32)> {
        self.records
            .iter()
            .filter(|a| a.kind.writes())
            .map(|a| (a.mode, a.row))
            .collect()
    }

    /// The wave-structured happens-before pass (see module docs).
    pub fn check(&self) -> Vec<RaceViolation> {
        let mut violations = Vec::new();
        let mut reported: BTreeSet<RaceViolation> = BTreeSet::new();
        let mut report = |v: RaceViolation, sink: &mut Vec<RaceViolation>| {
            // Dedup: one report per site, not per access pair.
            if reported.insert(v.clone()) {
                sink.push(v);
            }
        };

        // Group by (epoch, round, mode, row): the granularity every
        // rule below quantifies over.
        let mut sites: BTreeMap<(u32, u32, u32, u32), Vec<&Access>> = BTreeMap::new();
        for a in &self.records {
            sites
                .entry((a.prov.epoch, a.prov.round, a.mode, a.row))
                .or_default()
                .push(a);
        }

        for (&(epoch, round, mode, row), accesses) in &sites {
            // Latin level: per-worker write/any-access summary.
            let mut per_worker: BTreeMap<u32, bool> = BTreeMap::new();
            for a in accesses {
                let writes = per_worker.entry(a.prov.worker).or_insert(false);
                *writes |= a.kind.writes();
            }
            if per_worker.len() > 1 {
                let workers: Vec<(u32, bool)> =
                    per_worker.iter().map(|(&w, &wr)| (w, wr)).collect();
                for (i, &(wa, wra)) in workers.iter().enumerate() {
                    for &(wb, wrb) in workers.iter().skip(i + 1) {
                        if wra || wrb {
                            report(
                                RaceViolation::LatinRace {
                                    epoch,
                                    round,
                                    mode,
                                    row,
                                    worker_a: wa,
                                    worker_b: wb,
                                },
                                &mut violations,
                            );
                        }
                    }
                }
            }

            // Wave level: within (worker, wave), cross-thread overlap.
            let mut per_wave: BTreeMap<(u32, u32), Vec<&&Access>> = BTreeMap::new();
            for a in accesses {
                per_wave.entry((a.prov.worker, a.prov.wave)).or_default().push(a);
            }
            for (&(worker, wave), group) in &per_wave {
                let threads: BTreeSet<u32> = group.iter().map(|a| a.prov.thread).collect();
                if threads.len() < 2 {
                    continue;
                }
                // Plain write from one thread + anything from another.
                let plain_write_threads: BTreeSet<u32> = group
                    .iter()
                    .filter(|a| a.kind == AccessKind::Write)
                    .map(|a| a.prov.thread)
                    .collect();
                let cross_thread_plain_write = plain_write_threads
                    .iter()
                    .any(|t| group.iter().any(|a| a.prov.thread != *t));
                if cross_thread_plain_write {
                    report(
                        RaceViolation::WaveRace { epoch, round, worker, wave, mode, row },
                        &mut violations,
                    );
                }
                // Atomic + non-atomic from different threads.
                let atomic_threads: BTreeSet<u32> = group
                    .iter()
                    .filter(|a| a.kind == AccessKind::Atomic)
                    .map(|a| a.prov.thread)
                    .collect();
                let mixed = atomic_threads.iter().any(|t| {
                    group
                        .iter()
                        .any(|a| a.kind != AccessKind::Atomic && a.prov.thread != *t)
                });
                if mixed {
                    report(
                        RaceViolation::MixedAccessRace { epoch, round, worker, wave, mode, row },
                        &mut violations,
                    );
                }
            }
        }
        violations
    }

    /// Relaxed-contention histogram: for every `(epoch, round, worker,
    /// wave, mode, row)` site touched *atomically* by `k ≥ 2` distinct
    /// threads, bump bucket `k`. Empty means the run never actually
    /// contended (or never used the atomic path).
    pub fn overlap_histogram(&self) -> BTreeMap<u32, u64> {
        let mut threads_per_site: BTreeMap<(u32, u32, u32, u32, u32, u32), BTreeSet<u32>> =
            BTreeMap::new();
        for a in &self.records {
            if a.kind != AccessKind::Atomic {
                continue;
            }
            threads_per_site
                .entry((a.prov.epoch, a.prov.round, a.prov.worker, a.prov.wave, a.mode, a.row))
                .or_default()
                .insert(a.prov.thread);
        }
        let mut hist = BTreeMap::new();
        for threads in threads_per_site.values() {
            if threads.len() >= 2 {
                *hist.entry(threads.len() as u32).or_insert(0u64) += 1;
            }
        }
        hist
    }
}

// `RaceViolation` needs an order for the dedup set.
impl PartialOrd for RaceViolation {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for RaceViolation {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        fn key(v: &RaceViolation) -> (u8, u32, u32, u32, u32, u32, u32) {
            match *v {
                RaceViolation::LatinRace { epoch, round, mode, row, worker_a, worker_b } => {
                    (0, epoch, round, mode, row, worker_a, worker_b)
                }
                RaceViolation::WaveRace { epoch, round, worker, wave, mode, row } => {
                    (1, epoch, round, worker, wave, mode, row)
                }
                RaceViolation::MixedAccessRace { epoch, round, worker, wave, mode, row } => {
                    (2, epoch, round, worker, wave, mode, row)
                }
            }
        }
        key(self).cmp(&key(other))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[allow(clippy::too_many_arguments)]
    fn acc(
        kind: AccessKind,
        mode: u32,
        row: u32,
        epoch: u32,
        round: u32,
        worker: u32,
        wave: u32,
        thread: u32,
    ) -> Access {
        Access { mode, row, kind, prov: Provenance { epoch, round, worker, wave, thread } }
    }

    #[test]
    fn disjoint_structured_accesses_are_race_free() {
        // Two workers on different rows; two waves of one worker on the
        // same row (barrier-ordered); two threads of one wave on
        // different rows.
        let log = ShadowLog {
            records: vec![
                acc(AccessKind::Write, 0, 1, 0, 0, 0, 0, 0),
                acc(AccessKind::Write, 0, 2, 0, 0, 1, 0, 0),
                acc(AccessKind::Write, 1, 5, 0, 0, 0, 0, 0),
                acc(AccessKind::Write, 1, 5, 0, 0, 0, 1, 1),
                acc(AccessKind::Read, 2, 9, 0, 0, 0, 0, 0),
                acc(AccessKind::Read, 2, 9, 0, 0, 0, 0, 1),
            ],
        };
        assert_eq!(log.check(), vec![]);
        assert!(log.overlap_histogram().is_empty());
    }

    #[test]
    fn cross_worker_same_round_write_is_a_latin_race() {
        let log = ShadowLog {
            records: vec![
                acc(AccessKind::Write, 1, 7, 0, 3, 0, 0, 0),
                acc(AccessKind::Read, 1, 7, 0, 3, 2, 0, 0),
            ],
        };
        let v = log.check();
        assert_eq!(
            v,
            vec![RaceViolation::LatinRace {
                epoch: 0,
                round: 3,
                mode: 1,
                row: 7,
                worker_a: 0,
                worker_b: 2
            }]
        );
        // Same overlap in *different* rounds is barrier-ordered: legal.
        let log = ShadowLog {
            records: vec![
                acc(AccessKind::Write, 1, 7, 0, 3, 0, 0, 0),
                acc(AccessKind::Read, 1, 7, 0, 4, 2, 0, 0),
            ],
        };
        assert_eq!(log.check(), vec![]);
    }

    #[test]
    fn same_wave_cross_thread_write_is_a_wave_race() {
        let log = ShadowLog {
            records: vec![
                acc(AccessKind::Write, 0, 4, 1, 0, 0, 2, 0),
                acc(AccessKind::Read, 0, 4, 1, 0, 0, 2, 1),
            ],
        };
        assert_eq!(
            log.check(),
            vec![RaceViolation::WaveRace { epoch: 1, round: 0, worker: 0, wave: 2, mode: 0, row: 4 }]
        );
        // Same row, same wave, same *thread*: sequential, legal.
        let log = ShadowLog {
            records: vec![
                acc(AccessKind::Write, 0, 4, 1, 0, 0, 2, 1),
                acc(AccessKind::Read, 0, 4, 1, 0, 0, 2, 1),
            ],
        };
        assert_eq!(log.check(), vec![]);
    }

    #[test]
    fn atomic_overlap_feeds_histogram_not_violations() {
        let log = ShadowLog {
            records: vec![
                acc(AccessKind::Atomic, 1, 3, 0, 0, 0, 0, 0),
                acc(AccessKind::Atomic, 1, 3, 0, 0, 0, 0, 1),
                acc(AccessKind::Atomic, 1, 3, 0, 0, 0, 0, 2),
                acc(AccessKind::Atomic, 2, 8, 0, 0, 0, 0, 0),
            ],
        };
        assert_eq!(log.check(), vec![]);
        let hist = log.overlap_histogram();
        assert_eq!(hist.get(&3), Some(&1));
        assert_eq!(hist.len(), 1);
    }

    #[test]
    fn mixed_atomic_plain_access_is_reported() {
        let log = ShadowLog {
            records: vec![
                acc(AccessKind::Atomic, 1, 3, 0, 0, 0, 0, 0),
                acc(AccessKind::Write, 1, 3, 0, 0, 0, 0, 1),
            ],
        };
        let v = log.check();
        assert!(v.contains(&RaceViolation::MixedAccessRace {
            epoch: 0,
            round: 0,
            worker: 0,
            wave: 0,
            mode: 1,
            row: 3
        }));
    }

    // NOTE: session-based tests (begin/record/finish round trips) live
    // in `tests/shadow.rs`: with the `shadow-ledger` feature on, the
    // lib test binary's *other* tests drive instrumented engines on
    // parallel libtest threads, so an open session here would capture
    // their accesses too. The integration binary owns its process.

    #[test]
    fn written_rows_collects_write_ish_sites() {
        let log = ShadowLog {
            records: vec![
                acc(AccessKind::Read, 0, 1, 0, 0, 0, 0, 0),
                acc(AccessKind::Write, 0, 2, 0, 0, 0, 0, 0),
                acc(AccessKind::Atomic, 1, 3, 0, 0, 0, 0, 0),
            ],
        };
        let rows = log.written_rows();
        assert_eq!(rows, [(0, 2), (1, 3)].into_iter().collect());
    }
}
