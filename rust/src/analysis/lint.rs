//! Source lint for the crate's `unsafe` discipline, in the
//! `bench_support::regression` style: pure string-scanning functions
//! with unit-tested fixtures, plus one test that walks the real tree so
//! `cargo test` *is* the CI gate — no external linter binary.
//!
//! Two rules, both scoped to keep the unsafe surface frozen:
//!
//! 1. **Containment** — only the five audited modules
//!    ([`ALLOWED_UNSAFE_MODULES`]) may contain `unsafe` in `src/`. A new
//!    file that introduces `unsafe` fails CI until it is explicitly
//!    allowlisted here (and thereby pulled into the Miri/TSan/shadow
//!    coverage). Test and bench sources may exercise the unsafe API
//!    freely — rule 2 still applies to them.
//! 2. **Justification** — every line of code containing the `unsafe`
//!    token must have a `SAFETY` comment (`// SAFETY: ...` or a
//!    `/// # Safety` doc section) on the same line or within the
//!    [`LOOKBACK`] lines above it.
//!
//! The scanner is line-oriented: a line whose trimmed form starts with
//! `//` is a comment (searched for the `SAFETY` marker, never for the
//! token); on code lines only the part before a trailing `//` comment
//! is searched. That is deliberately simple — string literals are not
//! parsed — and the fixtures below pin exactly that behavior. This
//! file itself never spells the token outside comments: fixtures build
//! it at runtime from a placeholder.

use std::fs;
use std::io;
use std::path::Path;

/// The only `src/` modules allowed to contain `unsafe` code: the shared
/// factor view and its three consumers, each carrying the documented
/// three-level disjointness contract (see `parallel/shared.rs`), plus
/// the SIMD panel microkernels (ISSUE 10: raw-pointer intrinsic
/// loads/stores, bounds-justified per helper and differential-tested
/// bitwise against the scalar oracle).
pub const ALLOWED_UNSAFE_MODULES: &[&str] = &[
    "src/parallel/shared.rs",
    "src/kernel/dispatch.rs",
    "src/parallel/worker.rs",
    "src/algo/fasttucker.rs",
    "src/kernel/panel.rs",
];

/// How many lines above a flagged line may carry the `SAFETY` comment.
pub const LOOKBACK: usize = 12;

/// Which rule a finding violates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LintRule {
    /// `unsafe` in a `src/` file outside [`ALLOWED_UNSAFE_MODULES`].
    OutsideAllowlist,
    /// `unsafe` without a nearby `SAFETY` comment.
    MissingSafetyComment,
}

/// One lint hit: file, 1-based line, rule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LintFinding {
    pub file: String,
    pub line: usize,
    pub rule: LintRule,
}

impl std::fmt::Display for LintFinding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let what = match self.rule {
            LintRule::OutsideAllowlist => "outside the allowlisted modules",
            LintRule::MissingSafetyComment => "without a SAFETY comment",
        };
        write!(f, "{}:{}: {TOKEN} {what}", self.file, self.line)
    }
}

/// True when the line is purely a comment (`//`, `///`, `//!`).
fn is_comment_line(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// The code portion of a line: everything before a `//` comment.
fn code_part(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// True when the line carries a safety justification marker.
fn has_safety_marker(line: &str) -> bool {
    line.contains("SAFETY") || line.contains("# Safety")
}

/// The token under scrutiny, spelled in two halves so this file's own
/// code lines never contain it contiguously (the repo-walk test lints
/// this file too).
const TOKEN: &str = concat!("uns", "afe");

/// True when `code` contains the token as a standalone word.
fn contains_unsafe_token(code: &str) -> bool {
    let bytes = code.as_bytes();
    let word = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let mut from = 0;
    while let Some(pos) = code[from..].find(TOKEN) {
        let at = from + pos;
        let pre_ok = at == 0 || !word(bytes[at - 1]);
        let end = at + TOKEN.len();
        let post_ok = end >= bytes.len() || !word(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

/// Scan one source file. `file` is its path relative to the crate root
/// (used in findings and nothing else); `allowlisted` controls rule 1.
pub fn scan_source(file: &str, text: &str, allowlisted: bool) -> Vec<LintFinding> {
    let lines: Vec<&str> = text.lines().collect();
    let mut findings = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        if is_comment_line(line) {
            continue;
        }
        if !contains_unsafe_token(code_part(line)) {
            continue;
        }
        let lineno = idx + 1;
        if !allowlisted {
            findings.push(LintFinding {
                file: file.to_string(),
                line: lineno,
                rule: LintRule::OutsideAllowlist,
            });
        }
        let lo = idx.saturating_sub(LOOKBACK);
        let justified = lines[lo..=idx].iter().any(|l| has_safety_marker(l));
        if !justified {
            findings.push(LintFinding {
                file: file.to_string(),
                line: lineno,
                rule: LintRule::MissingSafetyComment,
            });
        }
    }
    findings
}

/// Walk `root` (the crate directory) and lint every `.rs` file under
/// `src/`, `tests/`, and `benches/`. `src/` files get the allowlist
/// rule; test and bench sources only the SAFETY-comment rule.
pub fn scan_tree(root: &Path) -> io::Result<Vec<LintFinding>> {
    let mut findings = Vec::new();
    for sub in ["src", "tests", "benches"] {
        let dir = root.join(sub);
        if !dir.is_dir() {
            continue;
        }
        let mut files = Vec::new();
        collect_rs_files(&dir, &mut files)?;
        files.sort();
        for path in files {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace(std::path::MAIN_SEPARATOR, "/");
            let allowlisted = if rel.starts_with("src/") {
                ALLOWED_UNSAFE_MODULES.contains(&rel.as_str())
            } else {
                true
            };
            let text = fs::read_to_string(&path)?;
            findings.extend(scan_source(&rel, &text, allowlisted));
        }
    }
    Ok(findings)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fixtures spell the token as `uns@afe` so this file itself stays
    /// clean under its own rule 1; `fix` rebuilds the real source.
    fn fix(s: &str) -> String {
        s.replace('@', "")
    }

    #[test]
    fn token_matching_is_word_bounded() {
        assert!(contains_unsafe_token(&fix("let x = uns@afe { y };")));
        assert!(contains_unsafe_token(&fix("uns@afe fn f() {}")));
        assert!(!contains_unsafe_token(&fix("let uns@afety = 1;")));
        assert!(!contains_unsafe_token(&fix("call_uns@afe()")));
        assert!(!contains_unsafe_token("perfectly safe code"));
    }

    #[test]
    fn comment_lines_never_flag() {
        let src = fix("// this mentions uns@afe code\n/// docs about uns@afe\nlet a = 1;\n");
        assert_eq!(scan_source("src/x.rs", &src, false), vec![]);
    }

    #[test]
    fn justified_block_passes_both_rules_when_allowlisted() {
        let src = fix(
            "fn f() {\n    // SAFETY: rows are disjoint per the wave contract.\n    \
             let r = uns@afe { g() };\n}\n",
        );
        assert_eq!(scan_source("src/parallel/shared.rs", &src, true), vec![]);
    }

    #[test]
    fn missing_safety_comment_is_flagged() {
        let src = fix("fn f() {\n    let r = uns@afe { g() };\n}\n");
        let findings = scan_source("src/parallel/shared.rs", &src, true);
        assert_eq!(
            findings,
            vec![LintFinding {
                file: "src/parallel/shared.rs".into(),
                line: 2,
                rule: LintRule::MissingSafetyComment,
            }]
        );
    }

    #[test]
    fn safety_comment_beyond_lookback_does_not_count() {
        let mut src = String::from("// SAFETY: way too far away.\n");
        for _ in 0..LOOKBACK {
            src.push_str("let pad = 0;\n");
        }
        src.push_str(&fix("let r = uns@afe { g() };\n"));
        let findings = scan_source("src/parallel/shared.rs", &src, true);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].rule, LintRule::MissingSafetyComment);
    }

    #[test]
    fn doc_safety_section_justifies_an_unsafe_fn() {
        let src = fix(
            "/// Does raw things.\n///\n/// # Safety\n/// Caller owns the rows.\n\
             pub uns@afe fn f() {}\n",
        );
        assert_eq!(scan_source("src/parallel/shared.rs", &src, true), vec![]);
    }

    #[test]
    fn non_allowlisted_file_is_flagged_even_when_justified() {
        let src = fix("// SAFETY: justified but misplaced.\nlet r = uns@afe { g() };\n");
        let findings = scan_source("src/metrics/mod.rs", &src, false);
        assert_eq!(
            findings,
            vec![LintFinding {
                file: "src/metrics/mod.rs".into(),
                line: 2,
                rule: LintRule::OutsideAllowlist,
            }]
        );
    }

    #[test]
    fn trailing_comment_code_split_is_respected() {
        // Token only inside the trailing comment: clean.
        let src = fix("let a = 1; // not uns@afe at all\n");
        assert_eq!(scan_source("src/x.rs", &src, false), vec![]);
        // Token in code, SAFETY in the same line's trailing comment.
        let src = fix("let r = uns@afe { g() }; // SAFETY: disjoint rows.\n");
        assert_eq!(scan_source("src/parallel/shared.rs", &src, true), vec![]);
    }

    /// The CI gate: the real tree must be clean. Runs as part of the
    /// normal test suite, so any new `unsafe` (or one that lost its
    /// justification) fails `cargo test` directly.
    #[test]
    fn repo_sources_pass_the_safety_lint() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = scan_tree(root).expect("walk crate sources");
        assert!(
            findings.is_empty(),
            "{TOKEN}-discipline lint failed:\n{}",
            findings
                .iter()
                .map(|f| format!("  {f}"))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
